"""Training launcher: ``python -m repro.launch.train --arch qwen2-1.5b
--steps 200 [--preset smoke|full] [--batch B --seq S]``.

Uses the reduced (smoke) preset by default so the e2e driver runs on CPU;
``--preset full`` uses the published config (TPU-scale)."""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    from repro import configs
    from repro.data.lm import TokenStream
    from repro.models import transformer as tfm
    from repro.train.loop import Trainer, TrainerConfig
    from repro.train.optimizer import AdamWConfig

    mod = configs.get(args.arch)
    if mod.FAMILY != "lm":
        raise SystemExit(f"train.py drives LM archs; {args.arch} is {mod.FAMILY}")
    cfg = mod.config() if args.preset == "full" else mod.smoke_config()
    if args.preset == "smoke":
        cfg = dataclasses.replace(cfg, dtype=jnp.float32)

    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"batch={args.batch} seq={args.seq} steps={args.steps}")

    stream = TokenStream(vocab=cfg.vocab, batch=args.batch, seq=args.seq)

    def data_at(step):
        b = stream.batch_at(step)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    trainer = Trainer(
        lambda p, b: tfm.loss_fn(p, b, cfg), params, data_at,
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, microbatch=args.microbatch),
        opt_cfg=AdamWConfig(lr=args.lr))
    result = trainer.run_with_restarts()
    for m in result["metrics"]:
        print(f"[train] step {m['step']:5d} loss {m['loss']:.4f} "
              f"({m['seconds']*1e3:.0f} ms)")
    print(json.dumps({"final_loss": result["metrics"][-1]["loss"],
                      "stragglers": result["stragglers"]}))


if __name__ == "__main__":
    main()
