"""Post-compile HLO analysis: collective-bytes accounting for §Roofline.

``cost_analysis()`` does not report collective traffic, so we parse the
optimized HLO text, sum the result byte-sizes of every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute),
and — crucially — multiply collectives that live inside ``while`` bodies
(scan over layers / chunks) by the loop trip count, recursively for nested
scans. All-reduce bytes are doubled per the ring-cost model.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_SHAPE_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_ELEM_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)"
    r"|while\(.*?\).*?body=%?([\w\.\-]+).*?condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:call|to_apply)=?\(?%?([\w\.\-]+)")


def _numel(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """Computation name -> body lines. Header lines are unindented and end
    with '{'; bodies are indented; '}' alone closes a computation."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            if not line.startswith(" ") and line.rstrip().endswith("{"):
                m = _COMP_START.match(line.strip())
                if m:
                    cur = m.group(1)
                    comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        comps[cur].append(line.strip())
    return comps


def _line_collective_bytes(line: str):
    """(kind, bytes) or None for one HLO line."""
    if "-done(" in line:
        return None
    m = _SHAPE_RE.search(line)
    if m:
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype in _DTYPE_BYTES:
            return kind, _numel(dims) * _DTYPE_BYTES[dtype]
        return None
    mt = _TUPLE_RE.search(line)
    if mt:
        kind = mt.group(2)
        size = 0
        for dtype, dims in _ELEM_RE.findall(mt.group(1)):
            if dtype in _DTYPE_BYTES:
                size += _numel(dims) * _DTYPE_BYTES[dtype]
        return kind, size
    return None


def collective_bytes(hlo_text: str) -> dict:
    """{kind: {count, bytes}, 'total_bytes': b} with while-body multiplicity."""
    comps = _split_computations(hlo_text)

    # trip counts: for each condition computation, the largest scalar constant
    cond_trip: dict[str, int] = {}
    for name, lines in comps.items():
        consts = [int(c) for line in lines for c in _CONST_RE.findall(line)]
        if consts:
            cond_trip[name] = max(consts)

    memo: dict[str, dict] = {}

    def walk(name: str, stack=()) -> dict:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return {}
        acc: dict = defaultdict(lambda: {"count": 0, "bytes": 0})
        for line in comps[name]:
            lc = _line_collective_bytes(line)
            if lc:
                kind, size = lc
                factor = 2 if kind == "all-reduce" else 1
                acc[kind]["count"] += 1
                acc[kind]["bytes"] += size * factor
            if re.search(r"\bwhile\(", line):
                wm = re.search(r"condition=%?([\w\.\-]+)", line)
                bm = re.search(r"body=%?([\w\.\-]+)", line)
                if wm and bm:
                    tm = _TRIP_RE.search(line)
                    if tm:
                        trips = int(tm.group(1))
                    else:
                        trips = cond_trip.get(wm.group(1), 1)
                    child = walk(bm.group(1), stack + (name,))
                    for kind, v in child.items():
                        if kind == "total_bytes":
                            continue
                        acc[kind]["count"] += v["count"] * trips
                        acc[kind]["bytes"] += v["bytes"] * trips
            elif "conditional(" in line or re.search(r"\bcall\(", line):
                for cm in re.finditer(
                        r"(?:true_computation|false_computation|to_apply|"
                        r"branch_computations=\{)%?([\w\.\-]+)", line):
                    child = walk(cm.group(1), stack + (name,))
                    for kind, v in child.items():
                        if kind == "total_bytes":
                            continue
                        acc[kind]["count"] += v["count"]
                        acc[kind]["bytes"] += v["bytes"]
        memo[name] = {k: dict(v) for k, v in acc.items()}
        return memo[name]

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    result = walk(entry) if entry else {}
    # fall back to flat count if entry parsing failed
    if not result:
        acc = defaultdict(lambda: {"count": 0, "bytes": 0})
        for line in hlo_text.splitlines():
            lc = _line_collective_bytes(line)
            if lc:
                kind, size = lc
                factor = 2 if kind == "all-reduce" else 1
                acc[kind]["count"] += 1
                acc[kind]["bytes"] += size * factor
        result = {k: dict(v) for k, v in acc.items()}
    result["total_bytes"] = sum(
        v["bytes"] for k, v in result.items() if k != "total_bytes")
    return result


def flops_and_bytes(cost: dict) -> tuple[float, float]:
    flops = float(cost.get("flops", 0.0))
    byt = float(cost.get("bytes accessed", 0.0))
    return flops, byt


# ---------------------------------------------------------------------------
# Trip-count-aware flops/bytes (XLA's cost_analysis counts while bodies ONCE
# — verified empirically: scan of 10 matmuls reports 1 matmul of flops).
# We walk entry -> while/call bodies multiplying by known_trip_count.
#   flops: dot ops (2 * numel(out) * contracted size) — the MXU term.
#   bytes: per top-level op: operand + output buffer bytes (fusion = its
#   boundary buffers only, internals live in registers/VMEM — the right
#   model for an HBM roofline). get-tuple-element/bitcast/tuple/parameter/
#   constant are free.
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^=]*?\))|(?:[a-z0-9]+"
    r"\[[0-9,]*\]))[^\s]*\s+([\w\-]+)\(")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\])")
_FREE_OPS = {"get-tuple-element", "tuple", "bitcast", "parameter", "constant",
             "iota", "after-all", "partition-id", "replica-id", "while",
             "conditional", "call", "custom-call",
             # dtype converts fuse into their consumers on TPU; the CPU
             # backend materializes bf16->f32 copies that a TPU never would
             "convert"}
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


# operands appear either bare ("%name") or typed ("f32[2,3]{1,0} %name" in
# newer XLA dumps); capture the inline type when present so shape lookups
# don't depend on the defining line being in the same computation
_OPERAND_RE = re.compile(r"(?:([a-z0-9]+\[[0-9,]*\])(?:\{[0-9,]*\})?\s+)?%([\w\.\-]+)")


def _operands(line: str, opcode: str) -> list[tuple]:
    """[(inline_type_or_None, name), ...] for one op's operand list."""
    m = re.search(re.escape(opcode) + r"\(([^)]*)\)", line)
    if not m:
        return []
    return [(t or None, n) for t, n in _OPERAND_RE.findall(m.group(1))]


def _type_bytes(t: str) -> int:
    """bytes of 'f32[2,3]' or '(f32[2], s32[])'."""
    total = 0
    for dtype, dims in _ELEM_RE.findall(t):
        if dtype in _DTYPE_BYTES:
            total += _numel(dims) * _DTYPE_BYTES[dtype]
    return total


def _first_shape(t: str):
    m = _ELEM_RE.search(t)
    if not m:
        return None, []
    dtype, dims = m.group(1), m.group(2)
    return dtype, [int(d) for d in dims.split(",")] if dims else []


def hlo_cost(hlo_text: str) -> dict:
    """{'flops': f, 'bytes': b} with while-trip multiplication."""
    raw_comps: dict[str, list[str]] = {}
    headers: dict[str, str] = {}
    cur = None
    for line in hlo_text.splitlines():
        if cur is None:
            if not line.startswith(" ") and line.rstrip().endswith("{"):
                m = _COMP_START.match(line.strip())
                if m:
                    cur = m.group(1)
                    raw_comps[cur] = []
                    headers[cur] = line
            continue
        if line.strip() == "}":
            cur = None
            continue
        raw_comps[cur].append(line.strip())

    # computations called as fusions / reducers are NOT walked for bytes
    fusion_bodies = set()
    for lines in raw_comps.values():
        for line in lines:
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", line):
                fusion_bodies.add(m.group(1))

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            entry = m.group(1) if m else None
            break

    memo: dict[str, tuple[float, float]] = {}

    def walk(name: str, stack=()) -> tuple[float, float]:
        if name in memo:
            return memo[name]
        if name not in raw_comps or name in stack:
            return (0.0, 0.0)
        shapes: dict[str, str] = {}
        for pm in _PARAM_RE.finditer(headers.get(name, "")):
            shapes[pm.group(1)] = pm.group(2)
        flops = 0.0
        byt = 0.0
        for line in raw_comps[name]:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            out_name, out_type, opcode = dm.groups()
            shapes[out_name] = out_type
            if opcode == "fusion":
                # walk nested flops (dots inside fusions still run on MXU)
                fm = re.search(r"calls=%?([\w\.\-]+)", line)
                if fm:
                    f_in, _ = walk(fm.group(1), stack + (name,))
                    flops += f_in
            if opcode == "while":
                wm = re.search(r"body=%?([\w\.\-]+)", line)
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                if wm:
                    f_in, b_in = walk(wm.group(1), stack + (name,))
                    flops += f_in * trips
                    byt += b_in * trips
                continue
            if opcode in ("call", "conditional"):
                for cm in re.finditer(
                        r"(?:to_apply|true_computation|false_computation)"
                        r"=%?([\w\.\-]+)", line):
                    f_in, b_in = walk(cm.group(1), stack + (name,))
                    flops += f_in
                    byt += b_in
                continue
            if opcode == "dot":
                opnds = _operands(line, opcode)
                cm = _CONTRACT_RE.search(line)
                contract = 1
                if cm and opnds:
                    lhs_t = opnds[0][0] or shapes.get(opnds[0][1])
                    if lhs_t:
                        _, dims = _first_shape(lhs_t)
                        for ci in (cm.group(1).split(",") if cm.group(1) else []):
                            i = int(ci)
                            if i < len(dims):
                                contract *= dims[i]
                _, out_dims = _first_shape(out_type)
                out_numel = 1
                for d in out_dims:
                    out_numel *= d
                flops += 2.0 * out_numel * contract
            if name in fusion_bodies:
                continue  # fusion internals don't touch HBM
            if opcode in _FREE_OPS:
                continue
            opnds = _operands(line, opcode)

            def _operand_type(i):
                if i >= len(opnds):
                    return None
                return opnds[i][0] or shapes.get(opnds[i][1])

            if opcode in ("dynamic-slice", "gather", "slice"):
                byt += 2 * _type_bytes(out_type)   # read slice + write
            elif opcode == "dynamic-update-slice" and len(opnds) > 1:
                upd = _operand_type(1)
                byt += 2 * (_type_bytes(upd) if upd else _type_bytes(out_type))
            elif opcode == "scatter" and len(opnds) > 2:
                upd = _operand_type(2)
                byt += 2 * (_type_bytes(upd) if upd else 0) + _type_bytes(out_type)
            else:
                b = _type_bytes(out_type)
                for i in range(len(opnds)):
                    t = _operand_type(i)
                    if t:
                        b += _type_bytes(t)
                byt += b
        memo[name] = (flops, byt)
        return memo[name]

    f, b = walk(entry) if entry else (0.0, 0.0)
    return {"flops": f, "bytes": b}
