"""Training substrate: optimizer, loops, microbatching, compression."""
