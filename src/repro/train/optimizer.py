"""AdamW with optional int8 gradient compression (error feedback).

States are plain pytrees so they shard with the same machinery as params
(ZeRO-style 'data'-axis sharding is applied by distributed.sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Pytree) -> Pytree:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads: Pytree, state: Pytree, params: Pytree,
                 cfg: AdamWConfig) -> tuple[Pytree, Pytree]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        newp = p - cfg.lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                             + cfg.weight_decay * p)
        return newp.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Gradient compression (int8 quantization with error feedback) — flag-gated
# distributed-optimization trick for DCN-bound multi-pod all-reduce.
# ---------------------------------------------------------------------------


def compress_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(tree: Pytree, axis: str, errors: Pytree
                    ) -> tuple[Pytree, Pytree]:
    """Quantize -> psum -> dequantize with error-feedback residuals. Cuts
    inter-pod gradient bytes 4x (fp32->int8); the residual keeps the update
    unbiased over steps (EF-SGD)."""
    def one(g, e):
        gc = g + e
        q, scale = compress_int8(gc)
        approx = decompress_int8(q, scale)
        new_e = gc - approx
        summed = jax.lax.psum(approx, axis)
        return summed, new_e

    flat_g, treedef = jax.tree.flatten(tree)
    flat_e = jax.tree.leaves(errors)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))
