"""Trainer: jit train step (+ optional microbatch gradient accumulation),
checkpoint/restart fault tolerance, straggler watchdog, deterministic data
replay. Works on any mesh (or none — single device) for any model exposing
(init_params, loss_fn)."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..distributed.fault import FailureInjector, StepWatchdog
from .optimizer import AdamWConfig, adamw_init, adamw_update

Pytree = Any


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    microbatch: int = 1          # gradient-accumulation splits
    log_every: int = 10
    async_ckpt: bool = True


class Trainer:
    def __init__(self, loss_fn: Callable, params: Pytree,
                 data_at: Callable[[int], dict], tcfg: TrainerConfig,
                 opt_cfg: AdamWConfig = AdamWConfig(),
                 failure_injector: Optional[FailureInjector] = None):
        self.loss_fn = loss_fn
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg
        self.data_at = data_at
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.watchdog = StepWatchdog()
        self.injector = failure_injector or FailureInjector()
        self.params = params
        self.opt_state = adamw_init(params)
        self.metrics: list[dict] = []

        mb = tcfg.microbatch

        def step_fn(params, opt_state, batch):
            if mb <= 1:
                (loss, aux), grads = jax.value_and_grad(
                    self.loss_fn, has_aux=True)(params, batch)
            else:
                def split(x):
                    return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
                micro = jax.tree.map(split, batch)

                def acc_step(carry, mb_batch):
                    gsum, lsum, asum = carry
                    (loss, aux), grads = jax.value_and_grad(
                        self.loss_fn, has_aux=True)(params, mb_batch)
                    gsum = jax.tree.map(jnp.add, gsum, grads)
                    return (gsum, lsum + loss, asum + aux), None

                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum, lsum, asum), _ = jax.lax.scan(
                    acc_step, (zero, jnp.float32(0), jnp.float32(0)), micro)
                grads = jax.tree.map(lambda g: g / mb, gsum)
                loss, aux = lsum / mb, asum / mb
            params, opt_state = adamw_update(grads, opt_state, params,
                                             self.opt_cfg)
            return params, opt_state, loss, aux

        self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------------ run
    def run(self, resume: bool = True) -> dict:
        start = 0
        if resume and self.ckpt.latest_step() is not None:
            state = {"params": self.params, "opt": self.opt_state}
            restored, meta = self.ckpt.restore(state)
            self.params = restored["params"]
            self.opt_state = restored["opt"]
            start = meta["step"] + 1

        for step in range(start, self.tcfg.total_steps):
            t0 = time.perf_counter()
            self.injector.maybe_fail(step)
            batch = self.data_at(step)
            self.params, self.opt_state, loss, aux = self._jit_step(
                self.params, self.opt_state, batch)
            dt = time.perf_counter() - t0
            straggler = self.watchdog.observe(step, dt)
            if step % self.tcfg.log_every == 0 or step == self.tcfg.total_steps - 1:
                self.metrics.append({"step": step, "loss": float(loss),
                                     "aux": float(aux), "seconds": dt,
                                     "straggler": straggler})
            if self.tcfg.ckpt_every and (step + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save(step, {"params": self.params,
                                      "opt": self.opt_state},
                               blocking=not self.tcfg.async_ckpt)
        self.ckpt.wait()
        return {"final_step": self.tcfg.total_steps - 1,
                "metrics": self.metrics,
                "stragglers": self.watchdog.straggler_steps}

    def run_with_restarts(self, max_restarts: int = 3) -> dict:
        """Supervised run: injected/real failures trigger restore-and-replay
        from the latest checkpoint (deterministic data makes replay exact)."""
        restarts = 0
        while True:
            try:
                return self.run(resume=True)
            except RuntimeError:
                restarts += 1
                if restarts > max_restarts:
                    raise
