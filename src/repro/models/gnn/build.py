"""GNN dry-run cell builder: (arch x shape) -> train step + ShapeDtypeStruct
inputs + shardings.

Sharding scheme (baseline):
  * edge arrays (src/dst/masks) — 'data'-sharded (edge-parallel MP)
  * node feature/label arrays — replicated (small) — the channel dim of
    irrep features shards over 'model' via parameter propagation
  * params — last dim sharded over 'model' when divisible (channel TP)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ...distributed import sharding as shr
from ...train.optimizer import AdamWConfig, adamw_init, adamw_update
from .common import GraphBatch


def _param_specs(params_shape, mesh: Mesh):
    tp = shr.axis_size(mesh, "model")

    def spec(leaf):
        if leaf.ndim >= 2 and leaf.shape[-1] % tp == 0 and leaf.shape[-1] >= tp:
            return P(*([None] * (leaf.ndim - 1) + ["model"]))
        return P()

    return jax.tree.map(spec, params_shape)


def _graph_args(spec: dict, arch: str, mesh: Mesh):
    """ShapeDtypeStruct batch + shardings for one shape spec."""
    dp = shr.dp_axes(mesh)
    equivariant = arch in ("mace", "equiformer_v2")
    kind = spec["kind"]
    if kind == "molecule":
        B, nn, ne = spec["batch"], spec["n_nodes"], spec["n_edges"]
        N, E = B * nn, B * ne
        n_graphs = B
    else:
        N, E = spec["n_nodes"], spec["n_edges"]
        n_graphs = 1
    E = -(-E // 512) * 512  # pad edges to a DP-shardable multiple (masked)

    f32, i32 = jnp.float32, jnp.int32
    batch = {
        "src": jax.ShapeDtypeStruct((E,), i32),
        "dst": jax.ShapeDtypeStruct((E,), i32),
        "edge_mask": jax.ShapeDtypeStruct((E,), f32),
    }
    shard = {
        "src": NamedSharding(mesh, P(dp)),
        "dst": NamedSharding(mesh, P(dp)),
        "edge_mask": NamedSharding(mesh, P(dp)),
    }
    if equivariant:
        batch["pos"] = jax.ShapeDtypeStruct((N, 3), f32)
        batch["species"] = jax.ShapeDtypeStruct((N,), i32)
        batch["labels"] = jax.ShapeDtypeStruct((n_graphs,), f32)
        shard["pos"] = NamedSharding(mesh, P())
        shard["species"] = NamedSharding(mesh, P())
        shard["labels"] = NamedSharding(mesh, P())
        if kind == "molecule":
            batch["graph_id"] = jax.ShapeDtypeStruct((N,), i32)
            shard["graph_id"] = NamedSharding(mesh, P())
    else:
        batch["x"] = jax.ShapeDtypeStruct((N, spec.get("d_feat", 16)), f32)
        batch["labels"] = jax.ShapeDtypeStruct((N,), i32)
        shard["x"] = NamedSharding(mesh, P())
        shard["labels"] = NamedSharding(mesh, P())
    if kind == "minibatch":
        batch["node_mask"] = jax.ShapeDtypeStruct((N,), f32)
        shard["node_mask"] = NamedSharding(mesh, P())
    return batch, shard, N, E, n_graphs


def build_cell(arch: str, shape_name: str, spec: dict, mesh: Mesh, Cell):
    from ... import configs as configs_pkg
    mod = configs_pkg.get(arch)
    equivariant = arch in ("mace", "equiformer_v2")
    kind = spec["kind"]

    import dataclasses
    import os
    if arch in ("gatedgcn", "pna"):
        readout = "graph" if kind == "molecule" else "node"
        d_in = spec.get("d_feat", 16) if kind != "molecule" else 16
        cfg = mod.config(d_in=d_in, n_classes=spec.get("n_classes", 1),
                         readout=readout)
    else:
        cfg = mod.config()
        if (arch == "equiformer_v2"
                and os.environ.get("REPRO_GNN_CHANNEL_SHARD") == "1"):
            cfg = dataclasses.replace(cfg, channel_shard_axis="model")  # §Perf E1

    if arch == "gatedgcn":
        from . import gatedgcn as m
    elif arch == "pna":
        from . import pna as m
    elif arch == "mace":
        from . import mace as m
    else:
        from . import equiformer_v2 as m

    batch_args, batch_shard, N, E, n_graphs = _graph_args(spec, arch, mesh)
    if arch in ("gatedgcn", "pna") and kind == "molecule":
        # feature-GNNs on molecule cells consume random node features
        batch_args["x"] = jax.ShapeDtypeStruct((N, 16), jnp.float32)
        batch_shard["x"] = NamedSharding(mesh, P())
        batch_args["graph_id"] = jax.ShapeDtypeStruct((N,), jnp.int32)
        batch_shard["graph_id"] = NamedSharding(mesh, P())
        batch_args["labels"] = jax.ShapeDtypeStruct((n_graphs,), jnp.float32)
        batch_shard["labels"] = NamedSharding(mesh, P())
        batch_args.pop("pos", None)
        batch_args.pop("species", None)

    params_shape = jax.eval_shape(
        lambda: m.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = _param_specs(params_shape, mesh)
    pshard = shr.tree_shardings(pspecs, mesh)
    opt_shape = jax.eval_shape(adamw_init, params_shape)
    ospecs = shr.opt_state_specs(pspecs, params_shape, mesh)
    oshard = shr.tree_shardings(ospecs, mesh)
    opt_cfg = AdamWConfig()
    ng = n_graphs

    def train_step(params, opt_state, batch):
        def loss(p):
            g = GraphBatch(
                src=batch["src"], dst=batch["dst"], x=batch.get("x"),
                pos=batch.get("pos"), species=batch.get("species"),
                node_mask=batch.get("node_mask"),
                edge_mask=batch.get("edge_mask"),
                graph_id=batch.get("graph_id"), n_graphs=ng)
            return m.loss_fn(p, g, batch["labels"], cfg)

        lval, grads = jax.value_and_grad(loss)(params)
        params, opt_state = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, lval

    n_params = int(sum(np.prod(l.shape) for l in jax.tree.leaves(params_shape)))
    return Cell(arch, shape_name, "gnn_train", train_step,
                (params_shape, opt_shape, batch_args),
                (pshard, oshard, batch_shard), donate_argnums=(0, 1),
                meta={"n_nodes": N, "n_edges": E, "n_params": n_params,
                      "n_graphs": ng, "fwd_bwd": True})
