"""Shared GNN substrate: message passing via segment reductions over an
edge index (JAX sparse is BCOO-only — scatter/segment IS the system here),
graph batch containers, and degree utilities.

The edge-index + segment_sum formulation is the same machinery as the
paper's CSR topology store (core.storage) — one gather per hop + one
scatter-reduce, which shards edge-parallel over the 'data' mesh axis."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Pytree = dict


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Static-shape (padded) graph batch.
    x: (N, F) node features; edge_index src/dst: (E,); edge_attr: (E, Fe);
    node_mask/edge_mask: validity; graph_id: (N,) for pooled readout over
    G graphs (batched small molecules); pos: (N, 3) for equivariant nets."""
    src: jax.Array
    dst: jax.Array
    x: Optional[jax.Array] = None
    edge_attr: Optional[jax.Array] = None
    pos: Optional[jax.Array] = None
    species: Optional[jax.Array] = None
    node_mask: Optional[jax.Array] = None
    edge_mask: Optional[jax.Array] = None
    graph_id: Optional[jax.Array] = None
    n_graphs: int = 1

    @property
    def n_nodes(self) -> int:
        for a in (self.x, self.pos, self.species):
            if a is not None:
                return a.shape[0]
        raise ValueError("empty batch")

    @property
    def n_edges(self) -> int:
        return self.src.shape[0]


def scatter_sum(messages: jax.Array, dst: jax.Array, n_nodes: int) -> jax.Array:
    return jax.ops.segment_sum(messages, dst, num_segments=n_nodes)


def scatter_max(messages: jax.Array, dst: jax.Array, n_nodes: int) -> jax.Array:
    return jax.ops.segment_max(messages, dst, num_segments=n_nodes)


def scatter_min(messages: jax.Array, dst: jax.Array, n_nodes: int) -> jax.Array:
    return jax.ops.segment_min(messages, dst, num_segments=n_nodes)


def scatter_mean(messages: jax.Array, dst: jax.Array, n_nodes: int,
                 eps: float = 1e-9) -> jax.Array:
    s = scatter_sum(messages, dst, n_nodes)
    cnt = jax.ops.segment_sum(jnp.ones((messages.shape[0], 1),
                                       messages.dtype), dst, n_nodes)
    return s / (cnt + eps)


def scatter_softmax(scores: jax.Array, dst: jax.Array, n_nodes: int
                    ) -> jax.Array:
    """Edge softmax: normalize scores over incoming edges of each dst node.
    scores: (E, H)."""
    smax = jax.ops.segment_max(scores, dst, num_segments=n_nodes)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    ex = jnp.exp(scores - smax[dst])
    denom = jax.ops.segment_sum(ex, dst, num_segments=n_nodes)
    return ex / (denom[dst] + 1e-16)


def degrees(dst: jax.Array, n_nodes: int, edge_mask=None) -> jax.Array:
    ones = jnp.ones_like(dst, jnp.float32)
    if edge_mask is not None:
        ones = ones * edge_mask
    return jax.ops.segment_sum(ones, dst, num_segments=n_nodes)


def graph_pool(x: jax.Array, graph_id: jax.Array, n_graphs: int,
               node_mask=None, mode: str = "sum") -> jax.Array:
    if node_mask is not None:
        x = x * node_mask[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(x, graph_id, num_segments=n_graphs)
    if mode == "mean":
        s = jax.ops.segment_sum(x, graph_id, num_segments=n_graphs)
        c = jax.ops.segment_sum(
            (node_mask if node_mask is not None
             else jnp.ones(x.shape[0], x.dtype)), graph_id, n_graphs)
        return s / jnp.maximum(c, 1)[:, None]
    raise ValueError(mode)


def mlp_params(rng, dims, name=""):
    keys = jax.random.split(rng, len(dims) - 1)
    return [{"w": jax.random.normal(k, (a, b), jnp.float32) * (a ** -0.5),
             "b": jnp.zeros((b,), jnp.float32)}
            for k, (a, b) in zip(keys, zip(dims[:-1], dims[1:]))]


def mlp_apply(layers, x, act=jax.nn.relu, final_act=False):
    for i, lyr in enumerate(layers):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x
