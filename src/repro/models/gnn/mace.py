"""MACE [arXiv:2206.07697]: higher-order equivariant message passing via the
ACE product basis.

Faithful structure, TPU-adapted:
  * A-basis: A_i = sum_j R(r_ij) * CG[ Y(r_hat_ij) (x) h_j ]  (one gather +
    segment_sum per CG path — the SpMM regime of the kernel taxonomy).
  * B-basis: iterated CG products A, (A(x)A), ((A(x)A)(x)A) up to the
    assigned correlation_order=3, path-weighted per channel. (The fully
    symmetrized generalized contraction of the paper is algebraically a
    re-parameterization of these iterated pairwise contractions restricted
    to l <= l_max; we document this simplification in DESIGN.md.)
  * Radial: n_rbf=8 Bessel basis with polynomial cutoff -> MLP -> per-path
    weights.

Config (assigned): n_layers=2, d_hidden=128 channels, l_max=2,
correlation_order=3, n_rbf=8, E(3)-equivariant (tested by rotation).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import so3
from .common import GraphBatch, mlp_apply, mlp_params, scatter_sum


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    channels: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    n_species: int = 16
    r_cut: float = 5.0

    @property
    def sh_dim(self) -> int:
        return so3.sh_dim(self.l_max)


def _paths(l_max: int):
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                out.append((l1, l2, l3))
    return out


def init_params(rng, cfg: MACEConfig):
    C, L = cfg.channels, cfg.n_layers
    paths = _paths(cfg.l_max)
    k = jax.random.split(rng, 8 + L)
    params = {
        "species_embed": jax.random.normal(k[0], (cfg.n_species, C)) * 0.3,
        "layers": [],
        "readouts": [],
    }
    for i in range(L):
        kk = jax.random.split(k[1 + i], 8)
        params["layers"].append({
            "radial": mlp_params(kk[0], [cfg.n_rbf, 64, len(paths) * C]),
            "w_msg": jax.random.normal(kk[1], (cfg.l_max + 1, C, C)) * C ** -0.5,
            "w_p2": jax.random.normal(kk[2], (len(paths), C)) * 0.3,
            "w_p3": jax.random.normal(kk[3], (len(paths), C)) * 0.3,
            "w_self": jax.random.normal(kk[4], (cfg.l_max + 1, C, C)) * C ** -0.5,
            "w_comb": jax.random.normal(kk[5], (3, cfg.l_max + 1, C)) * 0.5,
        })
        params["readouts"].append(mlp_params(jax.random.split(k[4 + L], 2)[0],
                                             [C, 64, 1]))
    return params


def _bessel(r, n_rbf, r_cut):
    """Bessel radial basis with smooth polynomial cutoff."""
    x = jnp.clip(r / r_cut, 1e-4, 1.0)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rb = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * math.pi * x[..., None]) / (
        x[..., None] * r_cut)
    u = 1 - 10 * x ** 3 + 15 * x ** 4 - 6 * x ** 5   # C2 cutoff poly
    return rb * u[..., None]


def _cg_combine(a, b, l_max, path_w, paths):
    """a, b: (B, dim, C) irreps; path_w: (n_paths, C) or per-path list.
    Returns (B, dim, C) = sum over paths of weighted CG products."""
    dim = so3.sh_dim(l_max)
    out = jnp.zeros(a.shape[:-2] + (dim, a.shape[-1]), a.dtype)
    for pi, (l1, l2, l3) in enumerate(paths):
        Ct = jnp.asarray(so3.real_cg(l1, l2, l3), a.dtype)
        s1, s2, s3 = l1 * l1, l2 * l2, l3 * l3
        blk = jnp.einsum("...ic,...jc,ijk->...kc",
                         a[..., s1:s1 + 2 * l1 + 1, :],
                         b[..., s2:s2 + 2 * l2 + 1, :], Ct)
        out = out.at[..., s3:s3 + 2 * l3 + 1, :].add(blk * path_w[pi])
    return out


def forward(params, g: GraphBatch, cfg: MACEConfig):
    """Returns per-graph energies (n_graphs,)."""
    N = g.n_nodes
    C, dim = cfg.channels, cfg.sh_dim
    paths = _paths(cfg.l_max)

    # node irreps: scalars initialized from species embedding
    h = jnp.zeros((N, dim, C), jnp.float32)
    h = h.at[:, 0, :].set(params["species_embed"][g.species])

    vec = g.pos[g.dst] - g.pos[g.src]
    r = jnp.linalg.norm(vec + 1e-12, axis=-1)
    r_hat = vec / (r[:, None] + 1e-9)
    Y = so3.real_sph_harm(r_hat, cfg.l_max)          # (E, dim)
    rbf = _bessel(r, cfg.n_rbf, cfg.r_cut)           # (E, n_rbf)
    edge_valid = (r > 1e-6).astype(jnp.float32)      # zero-length edges are
    if g.edge_mask is not None:                      # frame-degenerate: drop
        edge_valid = edge_valid * g.edge_mask

    energies = 0.0
    for lp, readout in zip(params["layers"], params["readouts"]):
        radial = mlp_apply(lp["radial"], rbf) * edge_valid[:, None]
        radial = radial.reshape(-1, len(paths), C)

        # --- A-basis: per-path CG of Y (as (E, dim, 1)) with h_src ---
        A = jnp.zeros((N, dim, C), jnp.float32)
        h_src = h[g.src]
        for pi, (l1, l2, l3) in enumerate(paths):
            Ct = jnp.asarray(so3.real_cg(l1, l2, l3), jnp.float32)
            s1, s2, s3 = l1 * l1, l2 * l2, l3 * l3
            msg = jnp.einsum("ei,ejc,ijk->ekc",
                             Y[:, s1:s1 + 2 * l1 + 1],
                             h_src[:, s2:s2 + 2 * l2 + 1, :], Ct)
            msg = msg * radial[:, pi, None, :]
            A = A.at[:, s3:s3 + 2 * l3 + 1, :].add(
                scatter_sum(msg, g.dst, N))
        # per-l channel mixing of the aggregated A-basis
        A_mixed = jnp.zeros_like(A)
        for l in range(cfg.l_max + 1):
            sl = slice(l * l, l * l + 2 * l + 1)
            A_mixed = A_mixed.at[:, sl, :].set(
                jnp.einsum("nmc,cd->nmd", A[:, sl, :], lp["w_msg"][l]))
        A = A_mixed

        # --- B-basis: iterated CG products (correlation order 3) ---
        B2 = _cg_combine(A, A, cfg.l_max, lp["w_p2"], paths)
        B3 = _cg_combine(B2, A, cfg.l_max, lp["w_p3"], paths)

        # --- update: per-l self-interaction + weighted B-basis sum ---
        h_new = jnp.zeros_like(h)
        for l in range(cfg.l_max + 1):
            s = l * l
            sl = slice(s, s + 2 * l + 1)
            self_mix = jnp.einsum("nmc,cd->nmd", h[:, sl, :], lp["w_self"][l])
            h_new = h_new.at[:, sl, :].set(
                self_mix
                + lp["w_comb"][0, l] * A[:, sl, :]
                + lp["w_comb"][1, l] * B2[:, sl, :]
                + lp["w_comb"][2, l] * B3[:, sl, :])
        h = h_new

        # --- readout from invariants ---
        node_e = mlp_apply(readout, h[:, 0, :])[:, 0]     # (N,)
        if g.node_mask is not None:
            node_e = node_e * g.node_mask
        energies = energies + jax.ops.segment_sum(
            node_e, g.graph_id if g.graph_id is not None
            else jnp.zeros((N,), jnp.int32), g.n_graphs)
    return energies


def loss_fn(params, g: GraphBatch, energy_labels, cfg: MACEConfig):
    pred = forward(params, g, cfg)
    return jnp.mean((pred - energy_labels) ** 2)
