"""EquiformerV2 [arXiv:2306.12059]: equivariant graph attention with eSCN
SO(2) convolutions.

The eSCN trick (the paper's O(L^6) -> O(L^3) reduction), TPU-adapted:
  1. rotate source-node irreps into the edge-aligned frame (Wigner-D per
     edge, batched as two einsums via the y-generator eigendecomposition in
     so3.py — no per-edge matrix exponentials),
  2. in that frame the tensor product with Y(edge) is block-diagonal in m:
     apply per-|m| dense channel mixing, with the (+m, -m) pair mixed by a
     2x2 rotation-structured weight [w_r, -w_i; w_i, w_r]; orders above
     m_max are dropped (the assigned m_max=2 truncation),
  3. rotate messages back, attention-weight them (scalar-channel MLP ->
     per-head logits -> segment softmax over incoming edges), scatter-sum.

Config (assigned): n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import so3
from .common import GraphBatch, mlp_apply, mlp_params, scatter_softmax, scatter_sum


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    channels: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_species: int = 16
    n_rbf: int = 8
    r_cut: float = 5.0
    # §Perf E1 (beyond-paper): shard node irrep channels over this mesh axis
    # so the per-layer edge-parallel aggregation all-reduces a C/n_shards
    # slice instead of the full (N, dim, C) tensor
    channel_shard_axis: str = ""

    @property
    def sh_dim(self) -> int:
        return so3.sh_dim(self.l_max)


def _m_index_sets(l_max: int, m_max: int):
    """For each |m| <= m_max: (rows_cos, rows_sin) index lists into the
    (l_max+1)^2 irrep vector; m=0 -> (rows, None)."""
    sets = []
    for m in range(m_max + 1):
        cos_rows = [l * l + l + m for l in range(m, l_max + 1)]
        sin_rows = [l * l + l - m for l in range(m, l_max + 1)] if m else None
        sets.append((cos_rows, sin_rows))
    return sets


def init_params(rng, cfg: EquiformerV2Config):
    C, H = cfg.channels, cfg.n_heads
    msets = _m_index_sets(cfg.l_max, cfg.m_max)
    k = jax.random.split(rng, 3 + cfg.n_layers)
    layers = []
    for i in range(cfg.n_layers):
        kk = jax.random.split(k[2 + i], 10)
        so2 = []
        for mi, (rows_c, rows_s) in enumerate(msets):
            nl = len(rows_c)
            fan = nl * C
            wr = jax.random.normal(kk[mi], (nl * C, nl * C)) * fan ** -0.5
            wi = (jax.random.normal(jax.random.fold_in(kk[mi], 7),
                                    (nl * C, nl * C)) * fan ** -0.5
                  if rows_s else None)
            so2.append({"wr": wr, "wi": wi})
        layers.append({
            "so2": so2,
            "radial": mlp_params(kk[8], [cfg.n_rbf, 64, C]),
            "attn": mlp_params(kk[7], [2 * C, C, H]),
            "w_val": jax.random.normal(kk[6], (C, C)) * C ** -0.5,
            "ffn_gate": mlp_params(jax.random.fold_in(kk[5], 1), [C, C * 2]),
            "ffn_mix": jax.random.normal(jax.random.fold_in(kk[5], 2),
                                         (cfg.l_max + 1, C, C)) * C ** -0.5,
            "ln": jnp.ones((cfg.l_max + 1, C)),
        })
    return {
        "species_embed": jax.random.normal(k[0], (cfg.n_species, C)) * 0.3,
        "layers": layers,
        "readout": mlp_params(k[1], [C, 64, 1]),
    }


def _irrep_norm(h, gains, l_max):
    """Per-l RMS norm over (m, channel)."""
    out = jnp.zeros_like(h)
    for l in range(l_max + 1):
        sl = slice(l * l, l * l + 2 * l + 1)
        blk = h[:, sl, :]
        rms = jnp.sqrt(jnp.mean(blk * blk, axis=(1, 2), keepdims=True) + 1e-6)
        out = out.at[:, sl, :].set(blk / rms * gains[l])
    return out


def _so2_conv(feat_edge, so2_w, radial, msets, C):
    """feat_edge: (E, dim, C) in edge frame. Per-|m| dense mixing over
    (l-stack x channels); radial (E, C) modulates channels."""
    out = jnp.zeros_like(feat_edge)
    for (rows_c, rows_s), w in zip(msets, so2_w):
        nl = len(rows_c)
        fc = feat_edge[:, jnp.array(rows_c), :].reshape(-1, nl * C)
        if rows_s is None:
            oc = fc @ w["wr"]
            oc = oc.reshape(-1, nl, C) * radial[:, None, :]
            out = out.at[:, jnp.array(rows_c), :].set(oc)
        else:
            fs = feat_edge[:, jnp.array(rows_s), :].reshape(-1, nl * C)
            oc = fc @ w["wr"] - fs @ w["wi"]
            os_ = fc @ w["wi"] + fs @ w["wr"]
            oc = oc.reshape(-1, nl, C) * radial[:, None, :]
            os_ = os_.reshape(-1, nl, C) * radial[:, None, :]
            out = out.at[:, jnp.array(rows_c), :].set(oc)
            out = out.at[:, jnp.array(rows_s), :].set(os_)
    return out


def forward(params, g: GraphBatch, cfg: EquiformerV2Config):
    from .mace import _bessel
    N = g.n_nodes
    C, dim, H = cfg.channels, cfg.sh_dim, cfg.n_heads
    msets = _m_index_sets(cfg.l_max, cfg.m_max)

    def _cshard(x):
        if not cfg.channel_shard_axis:
            return x
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            x, P(*([None] * (x.ndim - 1) + [cfg.channel_shard_axis])))

    h = jnp.zeros((N, dim, C), jnp.float32)
    h = h.at[:, 0, :].set(params["species_embed"][g.species])
    h = _cshard(h)

    vec = g.pos[g.dst] - g.pos[g.src]
    r = jnp.linalg.norm(vec + 1e-12, axis=-1)
    r_hat = vec / (r[:, None] + 1e-9)
    rbf = _bessel(r, cfg.n_rbf, cfg.r_cut)
    edge_valid = (r > 1e-6).astype(jnp.float32)      # zero-length edges are
    if g.edge_mask is not None:                      # frame-degenerate: drop
        edge_valid = edge_valid * g.edge_mask

    alpha, beta = so3.align_to_z_angles(r_hat)
    D = jnp.einsum("eij,ejk->eik", so3.dy_batch(-beta, cfg.l_max),
                   so3.dz_blocks(-alpha, cfg.l_max))      # (E, dim, dim)

    for lp in params["layers"]:
        hn = _irrep_norm(h, lp["ln"], cfg.l_max)
        radial = mlp_apply(lp["radial"], rbf) * edge_valid[:, None]  # (E, C)

        # eSCN message: rotate -> per-m SO(2) mixing -> rotate back
        src_feat = jnp.einsum("eij,ejc->eic", D, hn[g.src])
        msg_edge = _so2_conv(src_feat, lp["so2"], radial, msets, C)
        msg = jnp.einsum("eji,ejc->eic", D, msg_edge)     # back to global

        # attention over incoming edges from invariant channels
        inv = jnp.concatenate([hn[g.dst][:, 0, :], msg[:, 0, :]], -1)
        logits = mlp_apply(lp["attn"], inv)               # (E, H)
        if g.edge_mask is not None:
            logits = jnp.where(g.edge_mask[:, None] > 0, logits, -1e30)
        att = scatter_softmax(logits, g.dst, N)           # (E, H)
        # heads gate channel groups
        att_c = jnp.repeat(att, C // H, axis=-1)          # (E, C)
        val = jnp.einsum("eic,cd->eid", msg, lp["w_val"])
        agg = _cshard(scatter_sum(val * att_c[:, None, :], g.dst, N))
        h = h + agg

        # equivariant FFN: scalars gate all l-blocks
        hn2 = _irrep_norm(h, lp["ln"], cfg.l_max)
        gate = mlp_apply(lp["ffn_gate"], hn2[:, 0, :])    # (N, 2C)
        g1, g2 = gate[:, :C], gate[:, C:]
        up = jnp.zeros_like(h)
        for l in range(cfg.l_max + 1):
            sl = slice(l * l, l * l + 2 * l + 1)
            mixed = jnp.einsum("nmc,cd->nmd", hn2[:, sl, :], lp["ffn_mix"][l])
            gl = jax.nn.silu(g1) if l == 0 else jax.nn.sigmoid(g2)
            up = up.at[:, sl, :].set(mixed * gl[:, None, :])
        h = h + _cshard(up)

    node_e = mlp_apply(params["readout"], h[:, 0, :])[:, 0]
    if g.node_mask is not None:
        node_e = node_e * g.node_mask
    gid = g.graph_id if g.graph_id is not None else jnp.zeros((N,), jnp.int32)
    return jax.ops.segment_sum(node_e, gid, g.n_graphs)


def loss_fn(params, g: GraphBatch, energy_labels, cfg: EquiformerV2Config):
    pred = forward(params, g, cfg)
    return jnp.mean((pred - energy_labels) ** 2)
