"""PNA — Principal Neighbourhood Aggregation [arXiv:2004.05718]:
4 aggregators (mean/max/min/std) x 3 scalers (identity/amplification/
attenuation) -> 12-way concat -> linear, with a pairwise message MLP.

Config (assigned): n_layers=4, d_hidden=75, aggregators mean-max-min-std,
scalers id-amp-atten.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import (GraphBatch, degrees, graph_pool, mlp_apply, mlp_params,
                     scatter_max, scatter_mean, scatter_min, scatter_sum)


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 1433
    n_classes: int = 16
    avg_log_deg: float = 2.3      # normalizing constant (dataset statistic)
    readout: str = "node"


def init_params(rng, cfg: PNAConfig):
    d = cfg.d_hidden
    keys = jax.random.split(rng, cfg.n_layers * 2 + 2)
    layers = []
    for i in range(cfg.n_layers):
        layers.append({
            "msg": mlp_params(keys[2 * i], [2 * d, d, d]),
            "upd": mlp_params(keys[2 * i + 1], [12 * d + d, d]),
            "ln": jnp.ones((d,)),
        })
    return {
        "embed": jax.random.normal(keys[-2], (cfg.d_in, d)) * cfg.d_in ** -0.5,
        "layers": layers,     # list (heterogeneous MLPs) — python loop, 4 layers
        "head": jax.random.normal(keys[-1], (d, cfg.n_classes)) * d ** -0.5,
    }


def forward(params, g: GraphBatch, cfg: PNAConfig):
    n = g.n_nodes
    h = g.x @ params["embed"]
    deg = degrees(g.dst, n, g.edge_mask)
    log_deg = jnp.log(deg + 1.0)[:, None]
    amp = log_deg / cfg.avg_log_deg
    att = cfg.avg_log_deg / jnp.maximum(log_deg, 1e-6)

    for lp in params["layers"]:
        m = mlp_apply(lp["msg"], jnp.concatenate([h[g.src], h[g.dst]], -1))
        if g.edge_mask is not None:
            m = m * g.edge_mask[:, None]
        mean = scatter_mean(m, g.dst, n)
        mx = jnp.where(deg[:, None] > 0,
                       jnp.maximum(scatter_max(m, g.dst, n), -1e30), 0.0)
        mn = jnp.where(deg[:, None] > 0,
                       jnp.minimum(scatter_min(m, g.dst, n), 1e30), 0.0)
        var = scatter_mean(m * m, g.dst, n) - mean * mean
        std = jnp.sqrt(jnp.maximum(var, 0.0) + 1e-10)
        aggs = jnp.concatenate([mean, mx, mn, std], -1)            # (N, 4d)
        scaled = jnp.concatenate([aggs, aggs * amp, aggs * att], -1)  # 12d
        mu = jnp.mean(h, -1, keepdims=True)
        var_h = jnp.var(h, -1, keepdims=True)
        h = h + mlp_apply(lp["upd"], jnp.concatenate([h, scaled], -1))
        h = (h - jnp.mean(h, -1, keepdims=True)) * jax.lax.rsqrt(
            jnp.var(h, -1, keepdims=True) + 1e-5) * lp["ln"]
    return h @ params["head"]


def loss_fn(params, g: GraphBatch, labels, cfg: PNAConfig):
    logits = forward(params, g, cfg)
    if cfg.readout == "graph":
        pooled = graph_pool(logits, g.graph_id, g.n_graphs, g.node_mask)
        return jnp.mean((pooled[:, 0] - labels) ** 2)
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
    mask = (labels >= 0).astype(jnp.float32)
    if g.node_mask is not None:
        mask = mask * g.node_mask
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1)
