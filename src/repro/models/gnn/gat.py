"""GAT / GATv2 [arXiv:1710.10903 / arXiv:2105.14491] — extra (non-assigned)
pool architecture exercising the SDDMM -> edge-softmax -> SpMM regime.

    e_ij = LeakyReLU(a^T [W h_i || W h_j])        (GAT)
    e_ij = a^T LeakyReLU(W [h_i || h_j])          (GATv2)
    alpha = edge_softmax(e); h'_i = ||_heads sum_j alpha_ij W h_j
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import GraphBatch, scatter_softmax, scatter_sum


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat"
    n_layers: int = 3
    d_hidden: int = 64
    n_heads: int = 4
    d_in: int = 1433
    n_classes: int = 7
    v2: bool = True
    negative_slope: float = 0.2


def init_params(rng, cfg: GATConfig):
    L, H, dh = cfg.n_layers, cfg.n_heads, cfg.d_hidden // cfg.n_heads
    k = jax.random.split(rng, 2 * L + 2)
    layers = []
    d_prev = cfg.d_hidden
    for i in range(L):
        layers.append({
            "W": jax.random.normal(k[2 * i], (d_prev, H, dh)) * d_prev ** -0.5,
            "a_src": jax.random.normal(k[2 * i + 1], (H, dh)) * dh ** -0.5,
            "a_dst": jax.random.normal(jax.random.fold_in(k[2 * i + 1], 1),
                                       (H, dh)) * dh ** -0.5,
        })
    return {"embed": jax.random.normal(k[-2], (cfg.d_in, cfg.d_hidden))
            * cfg.d_in ** -0.5,
            "layers": layers,
            "head": jax.random.normal(k[-1], (cfg.d_hidden, cfg.n_classes))
            * cfg.d_hidden ** -0.5}


def forward(params, g: GraphBatch, cfg: GATConfig):
    n = g.n_nodes
    H, dh = cfg.n_heads, cfg.d_hidden // cfg.n_heads
    h = g.x @ params["embed"]
    slope = cfg.negative_slope
    for lp in params["layers"]:
        hw = jnp.einsum("nd,dhe->nhe", h, lp["W"])          # (N, H, dh)
        if cfg.v2:
            z = hw[g.src] + hw[g.dst]                        # (E, H, dh)
            scores = jnp.einsum("ehd,hd->eh",
                                jax.nn.leaky_relu(z, slope), lp["a_src"])
        else:
            s_src = jnp.einsum("nhe,he->nh", hw, lp["a_src"])
            s_dst = jnp.einsum("nhe,he->nh", hw, lp["a_dst"])
            scores = jax.nn.leaky_relu(s_src[g.src] + s_dst[g.dst], slope)
        if g.edge_mask is not None:
            scores = jnp.where(g.edge_mask[:, None] > 0, scores, -1e30)
        alpha = scatter_softmax(scores, g.dst, n)            # (E, H)
        msg = hw[g.src] * alpha[..., None]
        agg = scatter_sum(msg.reshape(-1, H * dh), g.dst, n)
        h = jax.nn.elu(agg) + h
    return h @ params["head"]


def loss_fn(params, g: GraphBatch, labels, cfg: GATConfig):
    logits = forward(params, g, cfg)
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
    mask = (labels >= 0).astype(jnp.float32)
    if g.node_mask is not None:
        mask = mask * g.node_mask
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1)
