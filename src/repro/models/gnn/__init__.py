"""GNN architectures: gatedgcn, pna (SpMM/segment regime) and mace,
equiformer_v2 (irrep tensor-product regime, eSCN-adapted)."""
