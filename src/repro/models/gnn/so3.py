"""SO(3) machinery for equivariant GNNs: real spherical harmonics (l <= 8),
Wigner-D rotations of real-SH irreps, and real Clebsch-Gordan coefficients.

TPU adaptation notes (vs the CUDA kernels of MACE/EquiformerV2):
  * SH evaluation is a vectorized associated-Legendre recurrence (VPU
    friendly, no lookup tables).
  * Wigner-D for an arbitrary rotation is decomposed as
        D(R) = Dz(alpha) @ Dy(beta) @ Dz(gamma)
    where Dz is closed-form (2x2 cos/sin blocks over m) and Dy(beta) is
    computed from the *eigendecomposition of the constant y-generator*
    K_y^l: Dy(beta) = Re[ U diag(e^{i m beta}) U^H ] — one complex einsum
    per edge batch instead of per-edge matrix exponentials.
  * Generators and CG tables are built once in numpy at import/config time
    (setup is O(l^6), runtime is pure einsum).
Everything is validated by property tests: D(R) Y(x) == Y(R x) and
CG equivariance under random rotations.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Real spherical harmonics via associated Legendre recurrence
# ---------------------------------------------------------------------------


def sh_dim(l_max: int) -> int:
    return (l_max + 1) ** 2


def real_sph_harm(xyz: jax.Array, l_max: int, eps: float = 1e-12) -> jax.Array:
    """xyz: (..., 3) (need not be normalized). Returns (..., (l_max+1)^2)
    real SH stacked l=0..l_max, m=-l..l (sin components for m<0)."""
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    r = jnp.sqrt(x * x + y * y + z * z + eps)
    ct = z / r                              # cos(theta)
    st = jnp.sqrt(jnp.clip(1.0 - ct * ct, 0.0, 1.0))
    rho = jnp.sqrt(x * x + y * y + eps)
    cp, sp = x / rho, y / rho               # cos/sin(phi)

    # associated Legendre P_l^m(ct) (no Condon-Shortley), stable recurrences
    P = {}
    P[(0, 0)] = jnp.ones_like(ct)
    for l in range(1, l_max + 1):
        P[(l, l)] = (2 * l - 1) * st * P[(l - 1, l - 1)]
    for l in range(1, l_max + 1):
        P[(l, l - 1)] = (2 * l - 1) * ct * P[(l - 1, l - 1)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[(l, m)] = ((2 * l - 1) * ct * P[(l - 1, m)]
                         - (l + m - 1) * P[(l - 2, m)]) / (l - m)

    # cos(m phi), sin(m phi) by recurrence
    cosm = [jnp.ones_like(cp), cp]
    sinm = [jnp.zeros_like(sp), sp]
    for m in range(2, l_max + 1):
        cosm.append(2 * cp * cosm[-1] - cosm[-2])
        sinm.append(2 * cp * sinm[-1] - sinm[-2])

    out = []
    for l in range(l_max + 1):
        row = [None] * (2 * l + 1)
        for m in range(0, l + 1):
            norm = math.sqrt((2 * l + 1) / (4 * math.pi)
                             * math.factorial(l - m) / math.factorial(l + m))
            if m == 0:
                row[l] = norm * P[(l, 0)]
            else:
                row[l + m] = math.sqrt(2) * norm * P[(l, m)] * cosm[m]
                row[l - m] = math.sqrt(2) * norm * P[(l, m)] * sinm[m]
        out.extend(row)
    return jnp.stack(out, axis=-1)


# ---------------------------------------------------------------------------
# numpy reference Wigner-D by least squares on sample directions (setup only)
# ---------------------------------------------------------------------------


def _np_sh(xyz: np.ndarray, l_max: int, eps: float = 1e-300) -> np.ndarray:
    """float64 numpy twin of real_sph_harm (setup-time accuracy)."""
    xyz = np.asarray(xyz, np.float64)
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    r = np.sqrt(x * x + y * y + z * z + eps)
    ct = z / r
    st = np.sqrt(np.clip(1.0 - ct * ct, 0.0, 1.0))
    rho = np.sqrt(x * x + y * y) + eps
    cp, sp = x / rho, y / rho
    P = {(0, 0): np.ones_like(ct)}
    for l in range(1, l_max + 1):
        P[(l, l)] = (2 * l - 1) * st * P[(l - 1, l - 1)]
    for l in range(1, l_max + 1):
        P[(l, l - 1)] = (2 * l - 1) * ct * P[(l - 1, l - 1)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[(l, m)] = ((2 * l - 1) * ct * P[(l - 1, m)]
                         - (l + m - 1) * P[(l - 2, m)]) / (l - m)
    cosm = [np.ones_like(cp), cp]
    sinm = [np.zeros_like(sp), sp]
    for m in range(2, l_max + 1):
        cosm.append(2 * cp * cosm[-1] - cosm[-2])
        sinm.append(2 * cp * sinm[-1] - sinm[-2])
    out = []
    for l in range(l_max + 1):
        row = [None] * (2 * l + 1)
        for m in range(0, l + 1):
            norm = math.sqrt((2 * l + 1) / (4 * math.pi)
                             * math.factorial(l - m) / math.factorial(l + m))
            if m == 0:
                row[l] = norm * P[(l, 0)]
            else:
                row[l + m] = math.sqrt(2) * norm * P[(l, m)] * cosm[m]
                row[l - m] = math.sqrt(2) * norm * P[(l, m)] * sinm[m]
        out.extend(row)
    return np.stack(out, axis=-1)


@functools.lru_cache(maxsize=None)
def _sample_dirs(l_max: int) -> np.ndarray:
    rng = np.random.default_rng(12345)
    n = 4 * sh_dim(l_max) + 8
    v = rng.standard_normal((n, 3))
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def wigner_np(l: int, R: np.ndarray) -> np.ndarray:
    """(2l+1)x(2l+1) real Wigner-D with Y_l(R x) = D Y_l(x), via lstsq."""
    dirs = _sample_dirs(max(l, 2))
    Y = _np_sh(dirs, l)[:, l * l:(l + 1) * (l + 1)]
    Yr = _np_sh(dirs @ R.T, l)[:, l * l:(l + 1) * (l + 1)]
    D, *_ = np.linalg.lstsq(Y, Yr, rcond=None)
    return D.T


def _rot_y(beta: float) -> np.ndarray:
    c, s = math.cos(beta), math.sin(beta)
    return np.array([[c, 0, s], [0, 1, 0], [-s, 0, c]])


def _rot_z(alpha: float) -> np.ndarray:
    c, s = math.cos(alpha), math.sin(alpha)
    return np.array([[c, -s, 0], [s, c, 0], [0, 0, 1]])


@functools.lru_cache(maxsize=None)
def y_generator_eig(l: int):
    """Eigendecomposition of the y-rotation generator K_y^l (antisymmetric):
    returns (U, m) complex eigenvectors and eigenvalue multipliers such that
    Dy(beta) = Re[U diag(exp(i m beta)) U^H]."""
    h = 1e-5
    Dp = wigner_np(l, _rot_y(h))
    Dm = wigner_np(l, _rot_y(-h))
    K = (Dp - Dm) / (2 * h)                  # antisymmetric generator
    K = 0.5 * (K - K.T)
    w, U = np.linalg.eig(K)                  # w = i*m
    m = np.round(w.imag).astype(np.float64)
    return U.astype(np.complex128), m


@functools.lru_cache(maxsize=None)
def _y_gen_stack(l_max: int):
    """Blocked (sh_dim, sh_dim) complex U and m arrays over l = 0..l_max."""
    dim = sh_dim(l_max)
    U = np.zeros((dim, dim), np.complex128)
    m = np.zeros((dim,), np.float64)
    for l in range(l_max + 1):
        Ul, ml = y_generator_eig(l)
        s = l * l
        U[s:s + 2 * l + 1, s:s + 2 * l + 1] = Ul
        m[s:s + 2 * l + 1] = ml
    return U, m


# ---------------------------------------------------------------------------
# Batched JAX Wigner rotations (edge-aligned frames)
# ---------------------------------------------------------------------------


def dz_blocks(alpha: jax.Array, l_max: int) -> jax.Array:
    """Block-diagonal Dz(alpha): (..., dim, dim). In the real-SH basis the
    z-rotation mixes (l, -m) and (l, +m): the m-th pair rotates by m*alpha."""
    dim = sh_dim(l_max)
    D = jnp.zeros(alpha.shape + (dim, dim), jnp.float32)
    for l in range(l_max + 1):
        s = l * l
        D = D.at[..., s + l, s + l].set(1.0)
        for m in range(1, l + 1):
            c, sn = jnp.cos(m * alpha), jnp.sin(m * alpha)
            # verified convention: column (+m) gains +sin on the (-m) row
            D = D.at[..., s + l - m, s + l - m].set(c)
            D = D.at[..., s + l - m, s + l + m].set(sn)
            D = D.at[..., s + l + m, s + l - m].set(-sn)
            D = D.at[..., s + l + m, s + l + m].set(c)
    return D


def dy_batch(beta: jax.Array, l_max: int) -> jax.Array:
    """Dy(beta): (..., dim, dim) via the precomputed generator eig."""
    U, m = _y_gen_stack(l_max)
    Uj = jnp.asarray(U, jnp.complex64)
    mj = jnp.asarray(m, jnp.float32)
    phase = jnp.exp(1j * mj * beta[..., None].astype(jnp.complex64))
    # D = U diag(phase) U^H
    D = jnp.einsum("ij,...j,kj->...ik", Uj, phase, jnp.conj(Uj))
    return jnp.real(D).astype(jnp.float32)


def wigner_from_rotation(alpha, beta, gamma, l_max: int) -> jax.Array:
    """D(Rz(alpha) Ry(beta) Rz(gamma)) batched over leading dims."""
    Dz_a = dz_blocks(alpha, l_max)
    Dy_b = dy_batch(beta, l_max)
    Dz_g = dz_blocks(gamma, l_max)
    return jnp.einsum("...ij,...jk,...kl->...il", Dz_a, Dy_b, Dz_g)


def align_to_z_angles(r_hat: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Angles (alpha, beta) with Ry(-beta) Rz(-alpha) r_hat = z_hat."""
    alpha = jnp.arctan2(r_hat[..., 1], r_hat[..., 0])
    beta = jnp.arccos(jnp.clip(r_hat[..., 2], -1.0, 1.0))
    return alpha, beta


def rotate_to_edge_frame(feats: jax.Array, r_hat: jax.Array, l_max: int
                         ) -> tuple[jax.Array, jax.Array]:
    """feats: (E, dim, C) irrep features; returns (rotated feats, D_inv).
    Rotation takes the edge direction to +z (the eSCN trick: the subsequent
    per-m mixing is then SO(2)-block-diagonal)."""
    alpha, beta = align_to_z_angles(r_hat)
    zero = jnp.zeros_like(alpha)
    # R_align = Ry(-beta) Rz(-alpha)  =>  D = Dy(-beta) @ Dz(-alpha)
    D = jnp.einsum("...ij,...jk->...ik", dy_batch(-beta, l_max),
                   dz_blocks(-alpha, l_max))
    rotated = jnp.einsum("eij,ejc->eic", D, feats)
    return rotated, D  # D is orthogonal: D_inv = D^T


def rotate_from_edge_frame(feats: jax.Array, D: jax.Array) -> jax.Array:
    return jnp.einsum("eji,ejc->eic", D, feats)  # D^T f


# ---------------------------------------------------------------------------
# Clebsch-Gordan coefficients in the real-SH basis (numpy setup, cached)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _cg_complex(l1: int, l2: int, l3: int) -> np.ndarray:
    """Complex-basis CG <l1 m1 l2 m2 | l3 m3> via the Racah formula."""
    f = math.factorial

    def cg(j1, m1, j2, m2, j3, m3):
        if m1 + m2 != m3:
            return 0.0
        if not (abs(j1 - j2) <= j3 <= j1 + j2):
            return 0.0
        pre = math.sqrt(
            (2 * j3 + 1) * f(j3 + j1 - j2) * f(j3 - j1 + j2) * f(j1 + j2 - j3)
            / f(j1 + j2 + j3 + 1))
        pre *= math.sqrt(f(j3 + m3) * f(j3 - m3) * f(j1 - m1) * f(j1 + m1)
                         * f(j2 - m2) * f(j2 + m2))
        s = 0.0
        for k in range(0, j1 + j2 - j3 + 1):
            d1 = j1 + j2 - j3 - k
            d2 = j1 - m1 - k
            d3 = j2 + m2 - k
            d4 = j3 - j2 + m1 + k
            d5 = j3 - j1 - m2 + k
            if min(d1, d2, d3, d4, d5) < 0:
                continue
            s += (-1) ** k / (f(k) * f(d1) * f(d2) * f(d3) * f(d4) * f(d5))
        return pre * s

    out = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1))
    for i1, m1 in enumerate(range(-l1, l1 + 1)):
        for i2, m2 in enumerate(range(-l2, l2 + 1)):
            for i3, m3 in enumerate(range(-l3, l3 + 1)):
                out[i1, i2, i3] = cg(l1, m1, l2, m2, l3, m3)
    return out


@functools.lru_cache(maxsize=None)
def _real_to_complex(l: int) -> np.ndarray:
    """Unitary U with Y_complex = U @ Y_real (Condon-Shortley phase)."""
    dim = 2 * l + 1
    U = np.zeros((dim, dim), np.complex128)
    s2 = 1 / math.sqrt(2)
    for m in range(-l, l + 1):
        i = l + m  # row: complex m
        if m < 0:
            U[i, l + abs(m)] = s2                     # cos part
            U[i, l - abs(m)] = -1j * s2               # sin part
        elif m == 0:
            U[i, l] = 1.0
        else:
            U[i, l + m] = (-1) ** m * s2
            U[i, l - m] = 1j * (-1) ** m * s2
    return U


@functools.lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis CG tensor C with: (a x b)_k = sum_ij C[i,j,k] a_i b_j
    transforming as irrep l3 when a ~ l1, b ~ l2."""
    Cc = _cg_complex(l1, l2, l3)
    U1 = _real_to_complex(l1)
    U2 = _real_to_complex(l2)
    U3 = _real_to_complex(l3)
    # C_real[a,b,c] = sum_{m1,m2,m3} conj(U1[m1,a]) conj(U2[m2,b]) Cc U3[m3,c]
    C = np.einsum("ma,nb,mnp,pc->abc", np.conj(U1), np.conj(U2), Cc, U3)
    assert np.abs(C.imag).max() < 1e-9 or np.abs(C.real).max() < 1e-9, \
        (l1, l2, l3, np.abs(C.imag).max(), np.abs(C.real).max())
    # depending on parity the real CG is purely real or purely imaginary
    if np.abs(C.real).max() >= np.abs(C.imag).max():
        return np.ascontiguousarray(C.real)
    return np.ascontiguousarray(C.imag)
