"""GatedGCN [arXiv:2003.00982 benchmarking / arXiv:1711.07553]:
edge-gated message passing with explicit edge features.

    e'_ij = e_ij + ReLU( BN(A h_i + B h_j + C e_ij) )
    eta_ij = sigma(e'_ij) / (sum_j sigma(e'_ij) + eps)
    h'_i  = h_i + ReLU( BN(U h_i + sum_j eta_ij * (V h_j)) )

Config (assigned): n_layers=16, d_hidden=70, gated aggregator.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import GraphBatch, scatter_sum


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_hidden: int = 70
    d_in: int = 1433
    d_edge_in: int = 0
    n_classes: int = 16
    readout: str = "node"        # "node" classification | "graph" regression


def init_params(rng, cfg: GatedGCNConfig):
    L, d = cfg.n_layers, cfg.d_hidden
    k = jax.random.split(rng, 10)

    def w(key, *shape):
        return jax.random.normal(key, shape, jnp.float32) * (shape[0] ** -0.5)

    return {
        "embed_x": w(k[0], cfg.d_in, d),
        "embed_e": w(k[1], max(cfg.d_edge_in, 1), d),
        "layers": {
            "A": w(k[2], L, d, d), "B": w(k[3], L, d, d), "C": w(k[4], L, d, d),
            "U": w(k[5], L, d, d), "V": w(k[6], L, d, d),
            "ln_h": jnp.ones((L, d)), "ln_e": jnp.ones((L, d)),
        },
        "head": w(k[7], d, cfg.n_classes),
    }


def _ln(x, g):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g


def forward(params, g: GraphBatch, cfg: GatedGCNConfig):
    n = g.n_nodes
    h = g.x @ params["embed_x"]
    if g.edge_attr is not None:
        e = g.edge_attr @ params["embed_e"]
    else:
        e = jnp.zeros((g.n_edges, cfg.d_hidden), h.dtype)

    def layer(carry, lp):
        h, e = carry
        eh = h @ lp["A"]
        msg_src = h @ lp["B"]
        e_new = e + jax.nn.relu(_ln(eh[g.src] + msg_src[g.dst] + e @ lp["C"],
                                    lp["ln_e"]))
        gate = jax.nn.sigmoid(e_new)
        if g.edge_mask is not None:
            gate = gate * g.edge_mask[:, None]
        vh = (h @ lp["V"])[g.src]
        num = scatter_sum(gate * vh, g.dst, n)
        den = scatter_sum(gate, g.dst, n) + 1e-6
        h_new = h + jax.nn.relu(_ln(h @ lp["U"] + num / den, lp["ln_h"]))
        return (h_new, e_new), None

    (h, e), _ = jax.lax.scan(layer, (h, e), params["layers"])
    return h @ params["head"]


def loss_fn(params, g: GraphBatch, labels, cfg: GatedGCNConfig):
    logits = forward(params, g, cfg)
    if cfg.readout == "graph":
        from .common import graph_pool
        pooled = graph_pool(logits, g.graph_id, g.n_graphs, g.node_mask)
        return jnp.mean((pooled[:, 0] - labels) ** 2)
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
    mask = (labels >= 0).astype(jnp.float32)
    if g.node_mask is not None:
        mask = mask * g.node_mask
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1)
