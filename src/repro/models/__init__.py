"""Assigned architecture pool: LM transformers (dense + MoE), GNNs
(including equivariant), and recsys wide-deep."""
