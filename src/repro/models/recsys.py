"""Wide & Deep [arXiv:1606.07792] — n_sparse=40 fields, embed_dim=32,
deep MLP 1024-512-256, interaction=concat, plus a hashed-cross wide part.

The embedding LOOKUP is the hot path (JAX has no native EmbeddingBag): the
serving path uses gather + segment-sum (kernels/embedding_bag ships the
Pallas TPU version); tables are stacked (F, V, D) and row(vocab)-sharded
over the 'model' mesh axis (DLRM-style model parallelism). The final
training objective is logistic regression — the paper's REGRESSION GCDA
operator — and ``retrieval_step`` scores 1M candidates with a batched dot
(the SIMILARITY GCDA operator), not a loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class WideDeepConfig:
    name: str = "wide-deep"
    n_sparse: int = 40
    n_dense: int = 13
    embed_dim: int = 32
    vocab_per_field: int = 1_000_000
    wide_hash: int = 1_000_000
    mlp: tuple = (1024, 512, 256)
    tower_dim: int = 256           # retrieval tower output


def init_params(rng, cfg: WideDeepConfig):
    k = jax.random.split(rng, 8)
    F, V, D = cfg.n_sparse, cfg.vocab_per_field, cfg.embed_dim
    d_in = F * D + cfg.n_dense
    dims = (d_in,) + tuple(cfg.mlp)
    mlp = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        kk = jax.random.fold_in(k[1], i)
        mlp.append({"w": jax.random.normal(kk, (a, b), jnp.float32) * a ** -0.5,
                    "b": jnp.zeros((b,), jnp.float32)})
    return {
        "tables": jax.random.normal(k[0], (F, V, D), jnp.float32) * 0.01,
        "wide": jnp.zeros((cfg.wide_hash,), jnp.float32),
        "mlp": mlp,
        "head": jax.random.normal(k[2], (cfg.mlp[-1], 1), jnp.float32) * 0.05,
        "cand_proj": jax.random.normal(k[3], (cfg.mlp[-1], cfg.tower_dim),
                                       jnp.float32) * 0.06,
    }


def _hash_cross(sparse_idx: jax.Array, wide_hash: int) -> jax.Array:
    """Hashed pairwise cross features (field i x field i+1) -> wide ids."""
    a = sparse_idx[:, :-1].astype(jnp.uint32)
    b = sparse_idx[:, 1:].astype(jnp.uint32)
    h = (a * jnp.uint32(2654435761) ^ (b + jnp.uint32(0x9E3779B9)
                                       + (a << 6) + (a >> 2)))
    return (h % jnp.uint32(wide_hash)).astype(jnp.int32)


def forward(params, dense: jax.Array, sparse_idx: jax.Array,
            cfg: WideDeepConfig) -> jax.Array:
    """dense: (B, n_dense) float; sparse_idx: (B, F) int32. Returns logits."""
    B, F = sparse_idx.shape
    # embedding lookup: one gather per field over the stacked tables
    emb = jnp.einsum("fbd->bfd", jax.vmap(
        lambda table, idx: jnp.take(table, idx, axis=0),
        in_axes=(0, 1))(params["tables"], sparse_idx))      # (B, F, D)
    deep_in = jnp.concatenate([emb.reshape(B, -1), dense], -1)
    h = deep_in
    for lyr in params["mlp"]:
        h = jax.nn.relu(h @ lyr["w"] + lyr["b"])
    deep_logit = (h @ params["head"])[:, 0]
    cross_ids = _hash_cross(sparse_idx, cfg.wide_hash)      # (B, F-1)
    wide_logit = jnp.sum(jnp.take(params["wide"], cross_ids, axis=0), -1)
    return deep_logit + wide_logit


def user_tower(params, dense, sparse_idx, cfg) -> jax.Array:
    B, F = sparse_idx.shape
    emb = jnp.einsum("fbd->bfd", jax.vmap(
        lambda table, idx: jnp.take(table, idx, axis=0),
        in_axes=(0, 1))(params["tables"], sparse_idx))
    h = jnp.concatenate([emb.reshape(B, -1), dense], -1)
    for lyr in params["mlp"]:
        h = jax.nn.relu(h @ lyr["w"] + lyr["b"])
    return h @ params["cand_proj"]                          # (B, tower_dim)


def loss_fn(params, batch, cfg: WideDeepConfig):
    logits = forward(params, batch["dense"], batch["sparse"], cfg)
    y = batch["labels"]
    return jnp.mean(jax.nn.softplus(logits) - y * logits)   # logistic loss


def serve_step(params, dense, sparse_idx, cfg: WideDeepConfig):
    return jax.nn.sigmoid(forward(params, dense, sparse_idx, cfg))


def retrieval_step(params, dense, sparse_idx, candidates, cfg: WideDeepConfig,
                   top_k: int = 100):
    """Score one query batch against (n_cand, tower_dim) candidates with a
    single batched dot (the SIMILARITY GCDA pattern) + top-k."""
    q = user_tower(params, dense, sparse_idx, cfg)          # (B, T)
    qn = q * jax.lax.rsqrt(jnp.sum(q * q, -1, keepdims=True) + 1e-9)
    cn = candidates * jax.lax.rsqrt(
        jnp.sum(candidates * candidates, -1, keepdims=True) + 1e-9)
    scores = qn @ cn.T                                      # (B, n_cand)
    return jax.lax.top_k(scores, top_k)


def retrieval_step_distributed(params, dense, sparse_idx, candidates,
                               cfg: WideDeepConfig, mesh, top_k: int = 100):
    """§Perf R1: hierarchical top-k retrieval. Candidates are bf16 and
    sharded over BOTH mesh axes (('data','model')); each shard scores its
    slice against the (replicated, tiny) query tower output, takes a LOCAL
    top-k, and the winners are merged with one small all-gather — per-device
    HBM traffic drops by n_devices x 2 (bf16) and the cross-device traffic
    is top_k rows instead of the full score matrix."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    q = user_tower(params, dense, sparse_idx, cfg)
    qn = (q * jax.lax.rsqrt(jnp.sum(q * q, -1, keepdims=True) + 1e-9)
          ).astype(jnp.bfloat16)
    axes = tuple(mesh.axis_names)
    n_cand = candidates.shape[0]
    n_dev = mesh.devices.size
    per = n_cand // n_dev

    def local_fn(qn_l, cand_l):
        shard_lin = jax.lax.axis_index(axes)       # linearized over all axes
        cn = cand_l * jax.lax.rsqrt(
            jnp.sum(cand_l.astype(jnp.float32) ** 2, -1, keepdims=True)
            + 1e-9).astype(jnp.bfloat16)
        scores = jnp.einsum("bt,ct->bc", qn_l, cn,
                            preferred_element_type=jnp.float32)
        v, i = jax.lax.top_k(scores, min(top_k, per))      # local winners
        i = i + shard_lin * per                            # global ids
        v_all = jax.lax.all_gather(v, axes, axis=1, tiled=True)
        i_all = jax.lax.all_gather(i, axes, axis=1, tiled=True)
        vg, sel = jax.lax.top_k(v_all, top_k)              # merge
        ig = jnp.take_along_axis(i_all, sel, axis=1)
        return vg, ig

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P(axes, None)),
        out_specs=(P(), P()), check_rep=False)(qn, candidates)


# ---------------------------------------------------------------------------
# Synthetic batch pipeline
# ---------------------------------------------------------------------------


def random_batch(cfg: WideDeepConfig, batch: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "dense": jnp.asarray(rng.standard_normal((batch, cfg.n_dense)),
                             jnp.float32),
        "sparse": jnp.asarray(
            rng.integers(0, cfg.vocab_per_field, (batch, cfg.n_sparse)),
            jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 2, batch), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Dry-run cell builder
# ---------------------------------------------------------------------------


def build_cell(arch: str, shape_name: str, spec: dict, mesh: Mesh, Cell):
    from .. import configs as configs_pkg
    from ..distributed import sharding as shr
    from ..train.optimizer import AdamWConfig, adamw_init, adamw_update

    cfg = configs_pkg.get(arch).config()
    dp = shr.dp_axes(mesh)
    tp = shr.axis_size(mesh, "model")
    params_shape = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))

    import os
    if (os.environ.get("REPRO_RETRIEVAL_OPT") == "1"
            and spec["kind"] == "retrieval"
            and cfg.embed_dim % int(np.prod([shr.axis_size(mesh, a)
                                             for a in dp])) == 0):
        # §Perf R2: 2-D table sharding (vocab x embed-dim) — the local table
        # shard, which the sharded-gather lowering scans, shrinks by dp
        tables_spec = P(None, "model", dp)
    else:
        tables_spec = P(None, "model" if cfg.vocab_per_field % tp == 0
                        else None, None)
    pspecs = {
        "tables": tables_spec,
        "wide": P("model" if cfg.wide_hash % tp == 0 else None),
        "mlp": [{"w": P(), "b": P()} for _ in params_shape["mlp"]],
        "head": P(),
        "cand_proj": P(),
    }
    pshard = shr.tree_shardings(pspecs, mesh)

    B = spec["batch"]
    f32, i32 = jnp.float32, jnp.int32
    dense_s = jax.ShapeDtypeStruct((B, cfg.n_dense), f32)
    sparse_s = jax.ShapeDtypeStruct((B, cfg.n_sparse), i32)
    bsh = NamedSharding(mesh, P(dp, None))
    n_params = int(sum(np.prod(l.shape) for l in jax.tree.leaves(params_shape)))
    meta = {"n_params": n_params, "batch": B}

    if spec["kind"] == "train":
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        ospecs = shr.opt_state_specs(pspecs, params_shape, mesh)
        oshard = shr.tree_shardings(ospecs, mesh)
        opt_cfg = AdamWConfig()

        def train_step(params, opt_state, batch):
            lval, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
            params, opt_state = adamw_update(grads, opt_state, params, opt_cfg)
            return params, opt_state, lval

        args = (params_shape, opt_shape,
                {"dense": dense_s, "sparse": sparse_s,
                 "labels": jax.ShapeDtypeStruct((B,), f32)})
        in_sh = (pshard, oshard,
                 {"dense": bsh, "sparse": bsh,
                  "labels": NamedSharding(mesh, P(dp))})
        meta["fwd_bwd"] = True
        return Cell(arch, shape_name, "recsys_train", train_step, args, in_sh,
                    donate_argnums=(0, 1), meta=meta)

    if spec["kind"] == "retrieval":
        import os
        n_cand = spec["n_candidates"]
        if os.environ.get("REPRO_RETRIEVAL_OPT") == "1":   # §Perf R1
            n_cand = -(-n_cand // 512) * 512   # pad to a shardable multiple
            cand_s = jax.ShapeDtypeStruct((n_cand, cfg.tower_dim),
                                          jnp.bfloat16)
            axes = tuple(mesh.axis_names)

            def retr(params, dense, sparse, cands):
                return retrieval_step_distributed(params, dense, sparse,
                                                  cands, cfg, mesh)

            cand_sh = NamedSharding(mesh, P(axes, None))
        else:
            cand_s = jax.ShapeDtypeStruct((n_cand, cfg.tower_dim), f32)

            def retr(params, dense, sparse, cands):
                return retrieval_step(params, dense, sparse, cands, cfg)

            cand_sh = NamedSharding(mesh, P(dp, None))

        args = (params_shape, dense_s, sparse_s, cand_s)
        in_sh = (pshard, NamedSharding(mesh, P()), NamedSharding(mesh, P()),
                 cand_sh)
        meta.update({"fwd_bwd": False, "n_candidates": n_cand})
        return Cell(arch, shape_name, "recsys_retrieval", retr, args, in_sh,
                    meta=meta)

    def serve(params, dense, sparse):
        return serve_step(params, dense, sparse, cfg)

    args = (params_shape, dense_s, sparse_s)
    in_sh = (pshard, bsh, bsh)
    meta["fwd_bwd"] = False
    return Cell(arch, shape_name, "recsys_serve", serve, args, in_sh,
                meta=meta)
