"""Decoder-only LM transformer: dense + MoE, GQA, RoPE, optional QKV bias.

Framework notes (scale posture):
  * Parameters are stacked over layers and the layer loop is a single
    ``lax.scan`` — O(1) HLO size in depth, which keeps 512-device SPMD
    compiles tractable and enables per-layer remat.
  * Attention is a chunked double-scan (online softmax over KV chunks) — the
    pure-jnp analogue of the Pallas flash kernel, used off-TPU and inside
    dry-runs; on TPU the Pallas kernel is selected via ``attn_impl='flash'``.
  * MoE uses sort-based top-k dispatch into (E, C) capacity buffers — FLOPs
    scale with tokens*k*capacity_factor, not tokens*E (no dense-all-experts
    waste), and the expert dim shards over the 'model' mesh axis (EP).
  * All activations/constants are bf16 with fp32 params, RMSNorm/softmax/CE
    in fp32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "tiny"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 256
    vocab: int = 1000
    # MoE (n_experts=0 -> dense)
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    # variants
    qkv_bias: bool = False
    mlp: str = "swiglu"              # "swiglu" | "gelu"
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # execution
    d_head: int = 0                  # 0 -> d_model // n_heads
    attn_impl: str = "chunked"       # "chunked" | "dense" | "flash"
    q_chunk: int = 512
    kv_chunk: int = 1024
    attn_window: int = 0             # >0 -> sliding-window attention (opt-in)
    remat: bool = True
    dtype: Any = jnp.bfloat16
    ce_chunk: int = 256              # cross-entropy sequence chunking
    moe_groups: int = 1              # dispatch groups (== DP shards at scale,
                                     # so top-k sort/capacity stay shard-local)
    # distribution hooks (set by launch/specs.py; None/empty for local runs)
    mesh: Any = None                 # jax Mesh for shard_map-based paths
    mesh_dp: tuple = ()              # data-parallel axis names
    kv_seq_shard: str = ""           # mesh axis sharding the KV-cache seq dim
    moe_ep_axis: str = ""            # mesh axis for expert-parallel reshard
    moe_impl: str = "gspmd"          # "gspmd" | "shard_map" (§Perf M2)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        d, h, kv, dh, f, v, L = (self.d_model, self.n_heads, self.n_kv_heads,
                                 self.head_dim, self.d_ff, self.vocab, self.n_layers)
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        if self.qkv_bias:
            attn += (h + 2 * kv) * dh
        n_mats = 3 if self.mlp == "swiglu" else 2
        if self.is_moe:
            mlp = self.n_experts * n_mats * d * f + d * self.n_experts
        else:
            mlp = n_mats * d * f
        per_layer = attn + mlp + 2 * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        return L * per_layer + emb + d

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        n_mats = 3 if self.mlp == "swiglu" else 2
        dense_like = dataclasses.replace(self, n_experts=0)
        inactive = self.n_layers * n_mats * d * f * (self.n_experts - self.top_k)
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Parameter init (stacked layers)
# ---------------------------------------------------------------------------


def init_params(rng: jax.Array, cfg: TransformerConfig) -> Pytree:
    d, h, kv, dh, f, v, L = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.head_dim, cfg.d_ff, cfg.vocab, cfg.n_layers)
    keys = jax.random.split(rng, 12)

    def norm_init(k, *shape, scale=None):
        scale = scale if scale is not None else (shape[-2]) ** -0.5
        return (jax.random.normal(k, shape, jnp.float32) * scale)

    layer = {
        "wq": norm_init(keys[0], L, d, h * dh),
        "wk": norm_init(keys[1], L, d, kv * dh),
        "wv": norm_init(keys[2], L, d, kv * dh),
        "wo": norm_init(keys[3], L, h * dh, d),
        "ln1": jnp.ones((L, d), jnp.float32),
        "ln2": jnp.ones((L, d), jnp.float32),
    }
    if cfg.qkv_bias:
        layer["bq"] = jnp.zeros((L, h * dh), jnp.float32)
        layer["bk"] = jnp.zeros((L, kv * dh), jnp.float32)
        layer["bv"] = jnp.zeros((L, kv * dh), jnp.float32)
    if cfg.norm == "layernorm":
        layer["ln1_b"] = jnp.zeros((L, d), jnp.float32)
        layer["ln2_b"] = jnp.zeros((L, d), jnp.float32)

    n_mats = 3 if cfg.mlp == "swiglu" else 2
    if cfg.is_moe:
        E = cfg.n_experts
        layer["router"] = norm_init(keys[4], L, d, E)
        layer["w_in"] = norm_init(keys[5], L, E, d, f)
        if n_mats == 3:
            layer["w_gate"] = norm_init(keys[6], L, E, d, f)
        layer["w_out"] = norm_init(keys[7], L, E, f, d, scale=f ** -0.5)
    else:
        layer["w_in"] = norm_init(keys[5], L, d, f)
        if n_mats == 3:
            layer["w_gate"] = norm_init(keys[6], L, d, f)
        layer["w_out"] = norm_init(keys[7], L, f, d, scale=f ** -0.5)

    params = {
        "embed": jax.random.normal(keys[8], (v, d), jnp.float32) * 0.02,
        "ln_f": jnp.ones((d,), jnp.float32),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        params["head"] = norm_init(keys[9], d, v)
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def _norm(x, w, b=None):
    xf = x.astype(jnp.float32)
    if b is None:  # rmsnorm
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    y = y * w
    if b is not None:
        y = y + b
    return y.astype(x.dtype)


def _rope(x, positions, theta):
    """x: (B, S, H, Dh); positions: (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           -1).astype(x.dtype)


def _dense_attention(q, k, v, lengths, causal, window=0):
    """q: (B,H,S,D), k/v: (B,Hk,Skv,D). Oracle / small-shape path."""
    B, H, S, D = q.shape
    Hk, Skv = k.shape[1], k.shape[2]
    g = H // Hk
    qg = q.reshape(B, Hk, g, S, D)
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * D ** -0.5
    kpos = jnp.arange(Skv)[None, None, None, None, :]
    mask = kpos < lengths[:, None, None, None, None]
    qpos = (lengths[:, None, None, None, None] - S
            + jnp.arange(S)[None, None, None, :, None])
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, -1, keepdims=True)
    p = jnp.exp(s - m) * mask  # fully-masked rows -> exactly zero output
    p = p / jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgqc,bkcd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, D).astype(q.dtype)


def _chunked_attention(q, k, v, lengths, causal, q_chunk, kv_chunk, window=0):
    """Flash-style online-softmax double scan (jnp). Memory per step is
    O(B*H*qc*kc) instead of O(B*H*S*Skv)."""
    B, H, S, D = q.shape
    Hk, Skv = k.shape[1], k.shape[2]
    g = H // Hk
    qc = min(q_chunk, S)
    kc = min(kv_chunk, Skv)
    qpad, kpad = (-S) % qc, (-Skv) % kc
    q = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, 0), (0, kpad), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, kpad), (0, 0)))
    nq, nk = (S + qpad) // qc, (Skv + kpad) // kc
    qr = jnp.moveaxis(q.reshape(B, Hk, g, nq, qc, D), 3, 0)   # (nq,B,Hk,g,qc,D)
    kr = jnp.moveaxis(k.reshape(B, Hk, nk, kc, D), 2, 0)       # (nk,B,Hk,kc,D)
    vr = jnp.moveaxis(v.reshape(B, Hk, nk, kc, D), 2, 0)

    scale = D ** -0.5
    len_b = lengths[:, None, None, None, None]                  # (B,1,1,1,1)

    def q_step(_, qi):
        qblk, iq = qi                                           # (B,Hk,g,qc,D)
        qpos = (lengths[:, None, None, None, None] - S + iq * qc
                + jnp.arange(qc)[None, None, None, :, None])

        def kv_step(carry, kvj):
            m, l, acc = carry
            kblk, vblk, jk = kvj
            # preferred_element_type (not astype) so no f32 copy of the KV
            # cache is ever materialized — the MXU accumulates in f32
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            kpos = jk * kc + jnp.arange(kc)[None, None, None, None, :]
            mask = kpos < len_b
            if causal:
                mask &= qpos >= kpos
            if window:
                mask &= kpos > qpos - window
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, -1, keepdims=True))
            p = jnp.exp(s - m_new) * mask
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, -1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hk, g, qc, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hk, g, qc, 1), jnp.float32)
        a0 = jnp.zeros((B, Hk, g, qc, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kr, vr, jnp.arange(nk)))
        o = acc / jnp.where(l == 0.0, 1.0, l)
        return None, o.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qr, jnp.arange(nq)))  # (nq,B,Hk,g,qc,D)
    o = jnp.moveaxis(outs, 0, 3).reshape(B, Hk, g, (S + qpad), D)
    return o.reshape(B, H, S + qpad, D)[:, :, :S]


def _dist_decode_attention(q, k, v, lengths, cfg: TransformerConfig):
    """Distributed flash-decode: KV cache sharded on the SEQUENCE dim over
    ``cfg.kv_seq_shard``; each shard computes partial online-softmax stats
    (m, l, acc) over its KV slice and the shards merge with one pmax + two
    psums — per-device HBM traffic drops by the axis size (the §Perf D2
    optimization; beyond-paper, the paper's engine is single-node).

    q: (B, H, S, dh) batch-sharded; k/v: (B, Hk, M, dh) batch- and
    seq-sharded; lengths: (B,)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = cfg.kv_seq_shard
    dp = tuple(cfg.mesh_dp) or None
    B, H, S, Dh = q.shape
    Hk = k.shape[1]
    g = H // Hk
    scale = Dh ** -0.5

    def local_fn(qb, kb, vb, lb):
        idx = jax.lax.axis_index(axis)
        Bl = qb.shape[0]
        Ml = kb.shape[2]
        qg = qb.reshape(Bl, Hk, g, S, Dh)
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        kpos = idx * Ml + jnp.arange(Ml)[None, None, None, None, :]
        lb_b = lb[:, None, None, None, None]
        mask = kpos < lb_b
        qpos = (lb_b - S) + jnp.arange(S)[None, None, None, :, None]
        mask &= qpos >= kpos
        s = jnp.where(mask, s, -1e30)
        m = jnp.max(s, -1, keepdims=True)
        p = jnp.exp(s - m) * mask
        l = jnp.sum(p, -1, keepdims=True)
        acc = jnp.einsum("bkgqc,bkcd->bkgqd", p.astype(vb.dtype), vb,
                         preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, axis)
        acc_g = jax.lax.psum(acc * corr, axis)
        o = acc_g / jnp.where(l_g == 0.0, 1.0, l_g)
        return o.reshape(Bl, H, S, Dh).astype(qb.dtype)

    return shard_map(
        local_fn, mesh=cfg.mesh,
        in_specs=(P(dp, None, None, None), P(dp, None, axis, None),
                  P(dp, None, axis, None), P(dp)),
        out_specs=P(dp, None, None, None))(q, k, v, lengths)


def _moe_block(x, router_w, w_in, w_gate, w_out, cfg: TransformerConfig):
    """Sort-based top-k MoE dispatch, grouped-native. x: (G, T, d) with one
    group per DP shard -> sort/capacity are shard-local. Returns ((G, T, d),
    aux).

    With ``cfg.moe_ep_axis`` set, explicit sharding constraints pin the
    dispatch buffers to (dp, E-over-model) between the scatter and the
    expert einsums — GSPMD then lowers the reshard as token all-to-all
    (§Perf M1) instead of all-reducing the full dispatch buffer.
    """
    G, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(int(np.ceil(T * k / E * cfg.capacity_factor)), 1)

    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32), router_w)
    gates, idx = jax.lax.top_k(logits, k)                # (G, T, k)
    gates = jax.nn.softmax(gates, -1).astype(cfg.dtype)

    flat_e = idx.reshape(G, T * k)
    flat_gate = gates.reshape(G, T * k)
    order = jnp.argsort(flat_e, axis=-1)                 # stable
    sorted_e = jnp.take_along_axis(flat_e, order, -1)
    sorted_gate = jnp.take_along_axis(flat_gate, order, -1)
    token_of = order // k                                # (G, T*k)
    seg_start = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(
        sorted_e)                                        # (G, E)
    pos = jnp.arange(T * k)[None, :] - jnp.take_along_axis(
        seg_start, sorted_e, -1)
    keep = pos < C
    slot = jnp.where(keep, sorted_e * C + pos, E * C)    # E*C = drop bin

    xg = jnp.take_along_axis(x, token_of[..., None], axis=1)   # (G, T*k, d)
    upd = xg * keep[..., None]
    buf = jax.vmap(lambda s, u: jnp.zeros((E * C + 1, d), cfg.dtype)
                   .at[s].add(u))(slot, upd)
    xe = buf[:, :-1].reshape(G, E, C, d)
    xe = _ep_constraint(xe, cfg, expert_sharded=True)

    h = jnp.einsum("gecd,edf->gecf", xe, w_in.astype(cfg.dtype))
    if w_gate is not None:
        gatev = jnp.einsum("gecd,edf->gecf", xe, w_gate.astype(cfg.dtype))
        h = jax.nn.silu(gatev) * h
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("gecf,efd->gecd", h, w_out.astype(cfg.dtype))
    ye = _ep_constraint(ye, cfg, expert_sharded=False)

    ye_flat = ye.reshape(G, E * C, d)
    contrib = jnp.take_along_axis(
        ye_flat, jnp.where(keep, slot, 0)[..., None], axis=1) * jnp.where(
        keep, sorted_gate, jnp.zeros_like(sorted_gate))[..., None]
    out = jax.vmap(lambda t, c: jnp.zeros((T, d), cfg.dtype)
                   .at[t].add(c))(token_of, contrib)
    # load-balancing auxiliary loss (Switch): E * sum(fraction * prob)
    me = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), (0, 1))
    ce = jnp.mean(jax.nn.softmax(logits, -1), (0, 1))
    aux = E * jnp.sum(me * ce)
    return out, aux


def _moe_block_shard_map(x, router_w, w_in, w_gate, w_out,
                         cfg: TransformerConfig):
    """§Perf M2: expert-parallel MoE via shard_map. Activations are already
    replicated across the EP ('model') axis, so each expert shard routes and
    dispatches LOCALLY (zero dispatch collective: keep-mask restricted to
    its own expert range) and the only cross-shard traffic is the (G, T, d)
    partial-output psum — (T*d) bytes per layer instead of the (E*C*d)
    dispatch-buffer reshard + its 4.3GB/layer backward cotangent all-reduce
    that GSPMD generates for the constraint-based variant (M1)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = cfg.moe_ep_axis
    dp = tuple(cfg.mesh_dp) or None
    G, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    nsh = cfg.mesh.shape[axis]
    E_l = E // nsh
    C = max(int(np.ceil(T * k / E * cfg.capacity_factor)), 1)

    def local_fn(xl, router_l, w_in_l, w_gate_l, w_out_l):
        idx = jax.lax.axis_index(axis)
        base = idx * E_l
        Gl = xl.shape[0]
        logits = jnp.einsum("gtd,de->gte", xl.astype(jnp.float32), router_l)
        gates, top_i = jax.lax.top_k(logits, k)
        gates = jax.nn.softmax(gates, -1).astype(cfg.dtype)
        flat_e = top_i.reshape(Gl, T * k)
        flat_g = gates.reshape(Gl, T * k)
        order = jnp.argsort(flat_e, axis=-1)
        sorted_e = jnp.take_along_axis(flat_e, order, -1)
        sorted_gate = jnp.take_along_axis(flat_g, order, -1)
        token_of = order // k
        seg_start = jax.vmap(
            lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_e)
        pos = jnp.arange(T * k)[None, :] - jnp.take_along_axis(
            seg_start, sorted_e, -1)
        keep = (pos < C) & (sorted_e >= base) & (sorted_e < base + E_l)
        slot = jnp.where(keep, (sorted_e - base) * C + pos, E_l * C)

        xg = jnp.take_along_axis(xl, token_of[..., None], axis=1)
        upd = xg * keep[..., None]
        buf = jax.vmap(lambda s, u: jnp.zeros((E_l * C + 1, d), cfg.dtype)
                       .at[s].add(u))(slot, upd)
        xe = buf[:, :-1].reshape(Gl, E_l, C, d)
        h = jnp.einsum("gecd,edf->gecf", xe, w_in_l.astype(cfg.dtype))
        if w_gate_l is not None:
            gv = jnp.einsum("gecd,edf->gecf", xe, w_gate_l.astype(cfg.dtype))
            h = jax.nn.silu(gv) * h
        else:
            h = jax.nn.gelu(h)
        ye = jnp.einsum("gecf,efd->gecd", h, w_out_l.astype(cfg.dtype))
        ye_flat = ye.reshape(Gl, E_l * C, d)
        contrib = jnp.take_along_axis(
            ye_flat, jnp.where(keep, slot, 0)[..., None], axis=1) * jnp.where(
            keep, sorted_gate, jnp.zeros_like(sorted_gate))[..., None]
        out = jax.vmap(lambda t, c: jnp.zeros((T, d), cfg.dtype)
                       .at[t].add(c))(token_of, contrib)
        out = jax.lax.psum(out, axis)              # the only EP collective
        me = jnp.mean(jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32),
                      (0, 1))
        ce = jnp.mean(jax.nn.softmax(logits, -1), (0, 1))
        aux = E * jnp.sum(me * ce)
        if dp:
            aux = jax.lax.pmean(aux, dp)   # average the balance stat over DP
        return out, aux

    w_gate_spec = P(axis, None, None) if w_gate is not None else None
    args = [x, router_w, w_in]
    specs = [P(dp, None, None), P(), P(axis, None, None)]
    if w_gate is not None:
        args.append(w_gate)
        specs.append(P(axis, None, None))
        fn = lambda xl, r, wi, wg, wo: local_fn(xl, r, wi, wg, wo)
    else:
        fn = lambda xl, r, wi, wo: local_fn(xl, r, wi, None, wo)
    args.append(w_out)
    specs.append(P(axis, None, None))
    return shard_map(fn, mesh=cfg.mesh, in_specs=tuple(specs),
                     out_specs=(P(dp, None, None), P()),
                     check_rep=False)(*args)


def _ep_constraint(x, cfg: TransformerConfig, expert_sharded: bool):
    """(G, E, C, d) layout pin: G over DP; E over the EP axis pre-einsum,
    replicated (token layout) post-einsum."""
    if not cfg.moe_ep_axis or cfg.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = tuple(cfg.mesh_dp) or None
    spec = P(dp, cfg.moe_ep_axis if expert_sharded else None, None, None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(cfg.mesh, spec))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def forward(params: Pytree, tokens: jax.Array, cfg: TransformerConfig, *,
            lengths: Optional[jax.Array] = None,
            cache: Optional[Pytree] = None,
            cache_lengths: Optional[jax.Array] = None,
            return_hidden: bool = False):
    """tokens: (B, S). Training/prefill: cache=None. Decode: pass ``cache``
    {k,v: (L, B, Hk, S_max, dh)} and ``cache_lengths`` (B,) = tokens already
    in cache; returns (logits, new_cache).
    """
    B, S = tokens.shape
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)

    if cache is not None:
        positions = cache_lengths[:, None] + jnp.arange(S)[None, :]
        total_lengths = cache_lengths + S
    else:
        if lengths is None:
            lengths = jnp.full((B,), S, jnp.int32)
        positions = jnp.arange(S)[None, :].repeat(B, 0)
        total_lengths = lengths

    def layer_fn(carry, layer_and_cache):
        x = carry
        lp = layer_and_cache["p"]
        lcache = layer_and_cache.get("c")

        xa = _norm(x, lp["ln1"], lp.get("ln1_b"))
        q = jnp.einsum("bsd,dh->bsh", xa, lp["wq"].astype(cfg.dtype))
        kk = jnp.einsum("bsd,dh->bsh", xa, lp["wk"].astype(cfg.dtype))
        vv = jnp.einsum("bsd,dh->bsh", xa, lp["wv"].astype(cfg.dtype))
        if cfg.qkv_bias:
            q = q + lp["bq"].astype(cfg.dtype)
            kk = kk + lp["bk"].astype(cfg.dtype)
            vv = vv + lp["bv"].astype(cfg.dtype)
        q = q.reshape(B, S, h, dh)
        kk = kk.reshape(B, S, kv, dh)
        vv = vv.reshape(B, S, kv, dh)
        q = _rope(q, positions, cfg.rope_theta)
        kk = _rope(kk, positions, cfg.rope_theta)
        q = q.transpose(0, 2, 1, 3)          # (B, H, S, dh)
        kk = kk.transpose(0, 2, 1, 3)
        vv = vv.transpose(0, 2, 1, 3)

        new_lcache = None
        if lcache is not None:
            # decode: insert new kv at positions cache_lengths..+S
            kcache, vcache = lcache["k"], lcache["v"]

            def upd(c, new):
                # c: (B, Hk, M, dh); new: (B, Hk, S, dh); per-row start offset
                def one(c_b, new_b, start):
                    return jax.lax.dynamic_update_slice(c_b, new_b, (0, start, 0))
                return jax.vmap(one)(c, new, cache_lengths)

            kcache = upd(kcache, kk)
            vcache = upd(vcache, vv)
            new_lcache = {"k": kcache, "v": vcache}
            katt, vatt = kcache, vcache
            att_len = total_lengths
        else:
            katt, vatt = kk, vv
            att_len = total_lengths

        if cache is not None and cfg.kv_seq_shard:
            o = _dist_decode_attention(q, katt, vatt, att_len, cfg)
        elif cfg.attn_impl == "dense":
            o = _dense_attention(q, katt, vatt, att_len, True, cfg.attn_window)
        elif cfg.attn_impl == "flash":
            from ..kernels.flash_attention.ops import flash_attention
            o = flash_attention(q, katt, vatt, att_len, causal=True)
        else:
            o = _chunked_attention(q, katt, vatt, att_len, True,
                                   cfg.q_chunk, cfg.kv_chunk, cfg.attn_window)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, h * dh)
        x = x + jnp.einsum("bsh,hd->bsd", o, lp["wo"].astype(cfg.dtype))

        xm = _norm(x, lp["ln2"], lp.get("ln2_b"))
        if cfg.is_moe:
            G = max(1, min(cfg.moe_groups, B))
            xg = xm.reshape(G, B * S // G, d)
            block = (_moe_block_shard_map
                     if cfg.moe_impl == "shard_map" and cfg.moe_ep_axis
                     else _moe_block)
            moe = jax.checkpoint(  # nested remat: dispatch buffers are
                lambda xv: block(xv, lp["router"], lp["w_in"],
                                 lp.get("w_gate"), lp["w_out"], cfg),
                prevent_cse=False)
            y, aux = moe(xg)
            y = y.reshape(B, S, d)
        else:
            hmid = jnp.einsum("bsd,df->bsf", xm, lp["w_in"].astype(cfg.dtype))
            if cfg.mlp == "swiglu":
                gate = jnp.einsum("bsd,df->bsf", xm, lp["w_gate"].astype(cfg.dtype))
                hmid = jax.nn.silu(gate) * hmid
            else:
                hmid = jax.nn.gelu(hmid)
            y = jnp.einsum("bsf,fd->bsd", hmid, lp["w_out"].astype(cfg.dtype))
            aux = jnp.float32(0)
        x = x + y
        return x, (new_lcache, aux)

    body = layer_fn
    if cfg.remat:
        body = jax.checkpoint(layer_fn, prevent_cse=False)

    scan_in = {"p": params["layers"]}
    if cache is not None:
        scan_in["c"] = cache
    x, (new_cache, aux) = jax.lax.scan(body, x, scan_in)

    x = _norm(x, params["ln_f"])
    aux_loss = jnp.mean(aux)
    if return_hidden:
        return x, aux_loss
    head = params.get("head", None)
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))
    if cache is not None:
        return logits, new_cache
    return logits, aux_loss


# ---------------------------------------------------------------------------
# Train / serve steps
# ---------------------------------------------------------------------------


def loss_fn(params, batch, cfg: TransformerConfig):
    """Cross-entropy with sequence-chunked logits: the (B, Sc, V) logits
    block is produced, reduced, and discarded one chunk at a time inside a
    scan, so the full (B, S, V) tensor is never materialized."""
    hidden, aux = forward(params, batch["tokens"], cfg, return_hidden=True)
    labels = batch["labels"]
    B, S = labels.shape
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    head = head.astype(cfg.dtype)

    c = min(cfg.ce_chunk, S)
    pad = (-S) % c
    hidden_p = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    labels_p = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nchunk = (S + pad) // c
    h_r = jnp.moveaxis(hidden_p.reshape(B, nchunk, c, -1), 1, 0)
    l_r = jnp.moveaxis(labels_p.reshape(B, nchunk, c), 1, 0)

    def chunk_step(carry, hl):
        tot, cnt = carry
        h, lab = hl
        logits = jnp.einsum("bsd,dv->bsv", h, head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, jnp.maximum(lab, 0)[..., None],
                                   -1)[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        return (tot + jnp.sum((logz - gold) * mask), cnt + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(chunk_step, (jnp.float32(0), jnp.float32(0)),
                                 (h_r, l_r))
    nll = tot / jnp.maximum(cnt, 1)
    return nll + 0.01 * aux, nll


def init_cache(cfg: TransformerConfig, batch: int, max_len: int) -> Pytree:
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def serve_step(params, cache, tokens, cache_lengths, cfg: TransformerConfig):
    """One decode step: tokens (B, 1) new tokens; returns (next_token_logits,
    new_cache)."""
    logits, new_cache = forward(params, tokens, cfg, cache=cache,
                                cache_lengths=cache_lengths)
    return logits[:, -1], new_cache
