"""DCN-v2 [arXiv:2008.13535] — extra (non-assigned) pool architecture:
explicit low-rank cross network + deep tower over sparse embeddings.

    x_{l+1} = x_0 * (U_l (V_l^T x_l) + b_l) + x_l
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DCNv2Config:
    name: str = "dcn-v2"
    n_sparse: int = 26
    n_dense: int = 13
    embed_dim: int = 16
    vocab_per_field: int = 100_000
    n_cross: int = 3
    cross_rank: int = 64
    mlp: tuple = (256, 128)


def init_params(rng, cfg: DCNv2Config):
    k = jax.random.split(rng, 6 + cfg.n_cross)
    d0 = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    cross = []
    for i in range(cfg.n_cross):
        cross.append({
            "U": jax.random.normal(k[i], (d0, cfg.cross_rank)) * d0 ** -0.5,
            "V": jax.random.normal(jax.random.fold_in(k[i], 1),
                                   (d0, cfg.cross_rank)) * d0 ** -0.5,
            "b": jnp.zeros((d0,)),
        })
    dims = (d0,) + tuple(cfg.mlp)
    mlp = [{"w": jax.random.normal(jax.random.fold_in(k[-2], i),
                                   (a, b)) * a ** -0.5,
            "b": jnp.zeros((b,))}
           for i, (a, b) in enumerate(zip(dims[:-1], dims[1:]))]
    return {
        "tables": jax.random.normal(
            k[-3], (cfg.n_sparse, cfg.vocab_per_field, cfg.embed_dim)) * 0.01,
        "cross": cross,
        "mlp": mlp,
        "head": jax.random.normal(k[-1], (cfg.mlp[-1] + d0, 1)) * 0.05,
    }


def forward(params, dense, sparse_idx, cfg: DCNv2Config):
    B = sparse_idx.shape[0]
    emb = jnp.einsum("fbd->bfd", jax.vmap(
        lambda t, i: jnp.take(t, i, axis=0),
        in_axes=(0, 1))(params["tables"], sparse_idx))
    x0 = jnp.concatenate([emb.reshape(B, -1), dense], -1)
    x = x0
    for cp in params["cross"]:
        x = x0 * ((x @ cp["V"]) @ cp["U"].T + cp["b"]) + x
    h = x0
    for lyr in params["mlp"]:
        h = jax.nn.relu(h @ lyr["w"] + lyr["b"])
    return (jnp.concatenate([x, h], -1) @ params["head"])[:, 0]


def loss_fn(params, batch, cfg: DCNv2Config):
    logits = forward(params, batch["dense"], batch["sparse"], cfg)
    y = batch["labels"]
    return jnp.mean(jax.nn.softplus(logits) - y * logits)


def random_batch(cfg: DCNv2Config, batch: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "dense": jnp.asarray(rng.standard_normal((batch, cfg.n_dense)),
                             jnp.float32),
        "sparse": jnp.asarray(
            rng.integers(0, cfg.vocab_per_field, (batch, cfg.n_sparse)),
            jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 2, batch), jnp.float32),
    }
