"""qwen2-1.5b [arXiv:2407.10671; hf]: 28L d_model=1536 12H (GQA kv=2)
d_ff=8960 vocab=151936 — GQA, QKV bias, tied embeddings, SwiGLU."""
from ..models.transformer import TransformerConfig
from .lm_shapes import LM_SHAPES

FAMILY = "lm"
SHAPES = LM_SHAPES


def config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-1.5b", n_layers=28, d_model=1536, n_heads=12,
        n_kv_heads=2, d_ff=8960, vocab=151936, mlp="swiglu", norm="rmsnorm",
        qkv_bias=True, tie_embeddings=True, rope_theta=1000000.0)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, mlp="swiglu", qkv_bias=True,
        tie_embeddings=True)
