"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]: 24L
d_model=1024 16H (GQA kv=8) d_ff=512/expert, vocab=49155, MoE 32e top-8."""
from ..models.transformer import TransformerConfig
from .lm_shapes import LM_SHAPES

FAMILY = "lm"
SHAPES = LM_SHAPES


def config() -> TransformerConfig:
    return TransformerConfig(
        name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=8, d_ff=512, vocab=49155, n_experts=32, top_k=8,
        mlp="swiglu", norm="rmsnorm", tie_embeddings=True)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="granite-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=512, n_experts=4, top_k=2, mlp="swiglu",
        tie_embeddings=True)
