"""gatedgcn [arXiv:2003.00982]: n_layers=16 d_hidden=70 gated aggregator."""
from ..models.gnn.gatedgcn import GatedGCNConfig
from .gnn_shapes import GNN_SHAPES

FAMILY = "gnn"
SHAPES = GNN_SHAPES


def config(d_in: int = 1433, n_classes: int = 7,
           readout: str = "node") -> GatedGCNConfig:
    return GatedGCNConfig(name="gatedgcn", n_layers=16, d_hidden=70,
                          d_in=d_in, n_classes=n_classes, readout=readout)


def smoke_config() -> GatedGCNConfig:
    return GatedGCNConfig(name="gatedgcn-smoke", n_layers=2, d_hidden=16,
                          d_in=24, n_classes=4)
