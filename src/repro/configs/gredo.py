"""The paper's own workload config: the GredoDB engine over the M2Bench-style
e-commerce scenario (not part of the assigned dry-run cells — the engine's
GCDA kernels are exercised by benchmarks/ and the distributed GCDA path by
core.analytics.regression_distributed / multiply(mesh=...))."""

FAMILY = "db"
# Bonus dry-run cells (beyond the 40 assigned): the paper's GCDA operators
# at production scale on the same meshes.
SHAPES: dict = {
    "gcda_regression": {"kind": "gcda_regression", "rows": 4_194_304,
                        "features": 512},
    "gcda_similarity": {"kind": "gcda_similarity", "rows": 262_144,
                        "features": 256},
    "gcda_multiply": {"kind": "gcda_multiply", "m": 65_536, "k": 4_096,
                      "n": 65_536},
}


def config(sf: int = 1):
    from ..data import m2bench
    return {"sf": sf, "generator": m2bench.generate}


def smoke_config():
    return config(sf=1)
