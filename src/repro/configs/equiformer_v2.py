"""equiformer-v2 [arXiv:2306.12059]: n_layers=12 d_hidden=128 l_max=6
m_max=2 n_heads=8, SO(2)-eSCN equivariant graph attention."""
from ..models.gnn.equiformer_v2 import EquiformerV2Config
from .gnn_shapes import GNN_SHAPES

FAMILY = "gnn"
SHAPES = GNN_SHAPES


def config() -> EquiformerV2Config:
    return EquiformerV2Config(name="equiformer-v2", n_layers=12,
                              channels=128, l_max=6, m_max=2, n_heads=8)


def smoke_config() -> EquiformerV2Config:
    return EquiformerV2Config(name="eqv2-smoke", n_layers=2, channels=8,
                              l_max=3, m_max=2, n_heads=4, n_species=8)
