"""Architecture registry: one module per assigned arch (+ the paper's own
``gredo`` workload config). ``get(arch)`` -> module with:
  * ``config()``       — full published config
  * ``smoke_config()`` — reduced same-family config for CPU smoke tests
  * ``SHAPES``         — dict shape_name -> spec dict (the assigned cells)
  * ``FAMILY``         — "lm" | "gnn" | "recsys" | "db"
"""
from __future__ import annotations

import importlib

ARCHS = [
    # LM family
    "olmoe_1b_7b", "granite_moe_1b_a400m", "starcoder2_3b", "qwen2_1_5b",
    "stablelm_3b",
    # GNN
    "gatedgcn", "mace", "equiformer_v2", "pna",
    # RecSys
    "wide_deep",
    # the paper's own workload
    "gredo",
]


def get(arch: str):
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCHS}")
    return importlib.import_module(f"repro.configs.{arch}")


def all_cells(include_skipped: bool = False):
    """Yield (arch, shape_name, spec) for every assigned dry-run cell."""
    for arch in ARCHS:
        if arch == "gredo":
            continue
        mod = get(arch)
        for shape, spec in mod.SHAPES.items():
            if spec.get("skip") and not include_skipped:
                continue
            yield arch, shape, spec
