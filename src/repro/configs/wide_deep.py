"""wide-deep [arXiv:1606.07792]: n_sparse=40 embed_dim=32 mlp=1024-512-256
interaction=concat; tables 1M rows/field (row-sharded over 'model')."""
from ..models.recsys import WideDeepConfig

FAMILY = "recsys"

SHAPES = {
    "train_batch": {"kind": "train", "batch": 65536},
    "serve_p99": {"kind": "serve", "batch": 512},
    "serve_bulk": {"kind": "serve", "batch": 262144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1,
                       "n_candidates": 1_000_000},
}


def config() -> WideDeepConfig:
    return WideDeepConfig()


def smoke_config() -> WideDeepConfig:
    return WideDeepConfig(name="wide-deep-smoke", n_sparse=6, n_dense=4,
                          embed_dim=8, vocab_per_field=1000, wide_hash=512,
                          mlp=(32, 16), tower_dim=16)
