"""stablelm-3b [hf:stabilityai/stablelm-2-1_6b family; unverified]: 32L
d_model=2560 32H (kv=32, MHA) d_ff=6912 vocab=50304 — LayerNorm, SwiGLU."""
from ..models.transformer import TransformerConfig
from .lm_shapes import LM_SHAPES

FAMILY = "lm"
SHAPES = LM_SHAPES


def config() -> TransformerConfig:
    return TransformerConfig(
        name="stablelm-3b", n_layers=32, d_model=2560, n_heads=32,
        n_kv_heads=32, d_ff=6912, vocab=50304, mlp="swiglu",
        norm="layernorm", qkv_bias=False)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="stablelm-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=512, mlp="swiglu", norm="layernorm")
