"""starcoder2-3b [arXiv:2402.19173; hf]: 30L d_model=3072 24H (GQA kv=2)
d_ff=12288 vocab=49152 — GQA, RoPE, LayerNorm+bias, gelu MLP."""
from ..models.transformer import TransformerConfig
from .lm_shapes import LM_SHAPES

FAMILY = "lm"
SHAPES = LM_SHAPES


def config() -> TransformerConfig:
    return TransformerConfig(
        name="starcoder2-3b", n_layers=30, d_model=3072, n_heads=24,
        n_kv_heads=2, d_ff=12288, vocab=49152, mlp="gelu", norm="layernorm",
        qkv_bias=True)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="starcoder2-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, mlp="gelu", norm="layernorm",
        qkv_bias=True)
