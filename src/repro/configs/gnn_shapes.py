"""Shared GNN-family shape set (assigned).

* full_graph_sm — Cora-scale full-batch (2708 nodes / 10556 edges / 1433 f)
* minibatch_lg  — Reddit-scale (232 965 nodes / 114.6M edges) with a REAL
  fanout-(15,10) neighbor sampler over 1024 seed nodes; the dry-run lowers
  the train step on the sampler's padded static output shapes:
  nodes <= 1024*(1+15+15*10) = 169 984, edges <= 1024*15+15 360*10 = 168 960.
* ogb_products  — full-batch-large (2 449 029 nodes / 61 859 140 edges / 100 f)
* molecule      — batch=128 of 30-node/64-edge graphs (flattened: 3840/8192)

For the equivariant archs (mace, equiformer-v2) node inputs are positions +
species; the d_feat column sets of the citation-graph shapes are unused by
those archs (noted in DESIGN.md §Arch-applicability).
"""

FANOUT = (15, 10)
MB_SEEDS = 1024
MB_NODES = MB_SEEDS * (1 + FANOUT[0] + FANOUT[0] * FANOUT[1])
MB_EDGES = MB_SEEDS * FANOUT[0] + MB_SEEDS * FANOUT[0] * FANOUT[1]

GNN_SHAPES = {
    "full_graph_sm": {"kind": "full_graph", "n_nodes": 2708, "n_edges": 10556,
                      "d_feat": 1433, "n_classes": 7},
    "minibatch_lg": {"kind": "minibatch", "n_nodes": MB_NODES,
                     "n_edges": MB_EDGES, "d_feat": 602, "n_classes": 41,
                     "global_nodes": 232965, "global_edges": 114615892,
                     "batch_nodes": MB_SEEDS, "fanout": FANOUT},
    "ogb_products": {"kind": "full_graph", "n_nodes": 2449029,
                     "n_edges": 61859140, "d_feat": 100, "n_classes": 47},
    "molecule": {"kind": "molecule", "n_nodes": 30, "n_edges": 64,
                 "batch": 128},
}
