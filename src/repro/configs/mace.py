"""mace [arXiv:2206.07697]: n_layers=2 d_hidden=128 l_max=2
correlation_order=3 n_rbf=8, E(3)-equivariant ACE message passing."""
from ..models.gnn.mace import MACEConfig
from .gnn_shapes import GNN_SHAPES

FAMILY = "gnn"
SHAPES = GNN_SHAPES


def config() -> MACEConfig:
    return MACEConfig(name="mace", n_layers=2, channels=128, l_max=2,
                      correlation=3, n_rbf=8)


def smoke_config() -> MACEConfig:
    return MACEConfig(name="mace-smoke", n_layers=1, channels=8, l_max=2,
                      correlation=3, n_rbf=4, n_species=8)
