"""olmoe-1b-7b [arXiv:2409.02060; hf]: 16L d_model=2048 16H (kv=16)
d_ff=1024/expert, vocab=50304, MoE 64 experts top-8."""
from ..models.transformer import TransformerConfig
from .lm_shapes import LM_SHAPES

FAMILY = "lm"
SHAPES = LM_SHAPES


def config() -> TransformerConfig:
    return TransformerConfig(
        name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1024, vocab=50304, n_experts=64, top_k=8,
        mlp="swiglu", norm="rmsnorm", qkv_bias=False)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="olmoe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab=512, n_experts=8, top_k=2, mlp="swiglu")
