"""pna [arXiv:2004.05718]: n_layers=4 d_hidden=75, aggregators
mean-max-min-std, scalers id-amp-atten."""
from ..models.gnn.pna import PNAConfig
from .gnn_shapes import GNN_SHAPES

FAMILY = "gnn"
SHAPES = GNN_SHAPES


def config(d_in: int = 1433, n_classes: int = 7,
           readout: str = "node") -> PNAConfig:
    return PNAConfig(name="pna", n_layers=4, d_hidden=75, d_in=d_in,
                     n_classes=n_classes, readout=readout)


def smoke_config() -> PNAConfig:
    return PNAConfig(name="pna-smoke", n_layers=2, d_hidden=12, d_in=24,
                     n_classes=4)
