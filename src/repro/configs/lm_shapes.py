"""Shared LM-family shape set (assigned): seq_len x global_batch cells.

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len), NOT ``train_step``. ``long_500k`` requires sub-quadratic
attention: all five assigned LM archs are pure full-attention (GQA), so the
cell is marked skip (see DESIGN.md §Arch-applicability); the framework's
opt-in ``attn_window`` demonstrates the sub-quadratic path but is not part
of the faithful configs.
"""

LM_SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1,
                  "skip": "pure full-attention arch (sub-quadratic required)"},
}
