"""Fault tolerance + straggler mitigation.

Single-controller JAX gives SPMD steps that either complete everywhere or
fail; the fault model is therefore:
  * node/process failure  -> restart from CheckpointManager.latest (the
    Trainer's run loop catches failures, restores, and replays — the data
    pipeline is deterministic-by-step so replay is exact);
  * stragglers            -> detected by the StepWatchdog (EWMA of step
    times + threshold factor); mitigation = flag the step, optionally skip
    non-critical work (checkpoint/eval) on slow steps, and surface the
    event to the orchestration layer which can re-shard around the slow
    pod via distributed.elastic.
Failure *injection* (tests, chaos drills) is explicit via FailureInjector.
"""
from __future__ import annotations

import time
from typing import Callable, Optional


class StepWatchdog:
    def __init__(self, factor: float = 3.0, warmup: int = 5,
                 alpha: float = 0.1):
        self.factor = factor
        self.warmup = warmup
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.count = 0
        self.straggler_steps: list[int] = []

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step was a straggler."""
        self.count += 1
        if self.ewma is None:
            self.ewma = seconds
            return False
        is_straggler = (self.count > self.warmup
                        and seconds > self.factor * self.ewma)
        if is_straggler:
            self.straggler_steps.append(step)
        else:  # don't let stragglers poison the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        return is_straggler


class FailureInjector:
    """Deterministic chaos: raises at the configured steps (once each)."""

    def __init__(self, fail_at: tuple = (), exc=RuntimeError):
        self.fail_at = set(fail_at)
        self.exc = exc

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise self.exc(f"injected failure at step {step}")


def run_with_restarts(run: Callable[[Optional[int]], int],
                      max_restarts: int = 3) -> int:
    """Supervisor loop: ``run(resume_step)`` trains until done or raises.
    On failure, restart from the latest checkpoint (run re-reads it)."""
    restarts = 0
    while True:
        try:
            return run(None)
        except Exception:  # noqa: BLE001 — any worker failure
            restarts += 1
            if restarts > max_restarts:
                raise
