"""Sharding rules for every model family on the production mesh.

Mesh axes: ('data', 'model') single-pod, ('pod', 'data', 'model') multi-pod.
  * batch/data dims  -> ('pod','data') (DP; 'pod' composes hierarchically)
  * TP ('model')     -> attention heads / FFN hidden / MoE experts (EP) /
                        embedding vocab / recsys table rows
  * divisibility-checked: a dim is sharded only if divisible by the axis
    size; otherwise replicated (recorded — the roofline shows the cost, and
    the §Perf hillclimb addresses the worst case).
  * ZeRO: optimizer states additionally shard their largest replicated dim
    over 'data'.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _maybe(dim_size: int, n: int, axis="model"):
    """Shard a dim over `axis` only when divisible."""
    return axis if dim_size % n == 0 else None


# ---------------------------------------------------------------------------
# LM transformer
# ---------------------------------------------------------------------------


def lm_param_specs(cfg, mesh: Mesh) -> Pytree:
    from ..models.transformer import TransformerConfig  # noqa: F401
    tp = axis_size(mesh, "model")
    d, h, kv, dh, f, v = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, cfg.d_ff, cfg.vocab)
    E = cfg.n_experts
    h_ax = _maybe(h, tp)              # shard attention heads?
    kv_ax = _maybe(kv, tp)
    f_ax = _maybe(f, tp)
    v_ax = _maybe(v, tp)
    e_ax = _maybe(E, tp) if cfg.is_moe else None

    layers = {
        "wq": P(None, None, h_ax),
        "wk": P(None, None, kv_ax),
        "wv": P(None, None, kv_ax),
        "wo": P(None, h_ax, None),
        "ln1": P(), "ln2": P(),
    }
    if cfg.qkv_bias:
        layers["bq"] = P(None, h_ax)
        layers["bk"] = P(None, kv_ax)
        layers["bv"] = P(None, kv_ax)
    if cfg.norm == "layernorm":
        layers["ln1_b"] = P()
        layers["ln2_b"] = P()
    if cfg.is_moe:
        layers["router"] = P()
        layers["w_in"] = P(None, e_ax, None, None if e_ax else f_ax)
        layers["w_out"] = P(None, e_ax, None if e_ax else f_ax, None)
        if cfg.mlp == "swiglu":
            layers["w_gate"] = P(None, e_ax, None, None if e_ax else f_ax)
    else:
        layers["w_in"] = P(None, None, f_ax)
        layers["w_out"] = P(None, f_ax, None)
        if cfg.mlp == "swiglu":
            layers["w_gate"] = P(None, None, f_ax)

    specs = {
        "embed": P(v_ax, None) if v_ax else P(None, _maybe(d, tp)),
        "ln_f": P(),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        specs["head"] = P(None, v_ax) if v_ax else P(_maybe(d, tp), None)
    return specs


def lm_batch_spec(mesh: Mesh) -> P:
    return P(dp_axes(mesh), None)


def lm_cache_specs(cfg, mesh: Mesh, seq_shard: bool = False) -> Pytree:
    if seq_shard:  # D2 perf variant: KV seq dim sharded over 'model'
        spec = P(None, dp_axes(mesh), None, "model", None)
    else:
        kv_ax = _maybe(cfg.n_kv_heads, axis_size(mesh, "model"))
        spec = P(None, dp_axes(mesh), kv_ax, None, None)  # (L, B, Hk, M, dh)
    return {"k": spec, "v": spec}


# ---------------------------------------------------------------------------
# GNN: edge-parallel message passing
# ---------------------------------------------------------------------------


def gnn_data_specs(mesh: Mesh, replicate_nodes: bool = True) -> dict:
    dp = dp_axes(mesh)
    return {
        "edges": P(dp, None),                 # (E, 2) edge index, edge-parallel
        "nodes": P() if replicate_nodes else P(dp, None),
        "batch_nodes": P(dp, None),           # batched small graphs
    }


# ---------------------------------------------------------------------------
# RecSys: DLRM-style table-row sharding
# ---------------------------------------------------------------------------


def recsys_param_specs(params: Pytree, mesh: Mesh) -> Pytree:
    """Embedding tables row(vocab)-sharded over 'model'; dense replicated."""
    tp = axis_size(mesh, "model")

    def spec_for(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if "tables" in name and leaf.ndim == 2:
            return P(_maybe(leaf.shape[0], tp), None)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params)


# ---------------------------------------------------------------------------
# ZeRO optimizer-state sharding
# ---------------------------------------------------------------------------


def zero_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Add 'data' sharding on the largest unsharded, divisible dim."""
    n = axis_size(mesh, "data")
    entries = list(spec) + [None] * (len(shape) - len(spec))
    best, best_size = -1, 0
    for i, (s, dim) in enumerate(zip(entries, shape)):
        if s is None and dim % n == 0 and dim > best_size:
            best, best_size = i, dim
    if best >= 0:
        entries[best] = "data"
    return P(*entries)


def opt_state_specs(param_specs: Pytree, params_shape: Pytree, mesh: Mesh,
                    zero: bool = True) -> Pytree:
    def one(spec, shaped):
        if not zero:
            return spec
        return zero_spec(spec, shaped.shape, mesh)

    m = jax.tree.map(one, param_specs, params_shape)
    return {"m": m, "v": jax.tree.map(lambda s: s, m), "step": P()}


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def tree_shardings(specs: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs, is_leaf=lambda x: isinstance(x, P))
