"""Distribution layer: mesh-aware sharding rules per model family, ZeRO
optimizer-state sharding, elastic re-mesh, fault-tolerance utilities."""
