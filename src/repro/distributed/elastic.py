"""Elastic scaling: re-shard live training state onto a different mesh.

Checkpoints are host-complete (CheckpointManager), so growing/shrinking the
cluster is: drain -> checkpoint -> rebuild mesh -> restore with the new
shardings. ``reshard_state`` does the same transformation for a live pytree
(host-gather then device_put), used when the resize happens without going
through disk."""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

Pytree = Any


def host_gather(state: Pytree) -> Pytree:
    """Fully replicate to host numpy (works from any sharding)."""
    return jax.tree.map(lambda x: np.asarray(x), state)


def reshard_state(state: Pytree, new_shardings: Pytree) -> Pytree:
    host = host_gather(state)
    return jax.tree.map(lambda a, s: jax.device_put(a, s),
                        host, new_shardings)


def rebalanced_batch_size(global_batch: int, old_dp: int, new_dp: int) -> int:
    """Keep the global batch divisible by the new DP degree (round down to
    the nearest multiple; the Trainer rescales LR accordingly)."""
    per = max(global_batch // new_dp, 1)
    return per * new_dp
