PYTHONPATH := src
export PYTHONPATH

.PHONY: test verify test-fast bench-smoke bench bench-update bench-gcdia bench-optimizer

# tier-1 verification
test:
	python -m pytest -x -q

# alias used by CI / the verify skill
verify: test

# core engine + write-path tests only (quick inner loop)
test-fast:
	python -m pytest -x -q tests/test_storage.py tests/test_deltastore.py \
		tests/test_planner.py tests/test_system.py tests/test_oracle_equivalence.py

# small-size benchmark pass (CI smoke): paper suite fast mode + update +
# optimizer suites
bench-smoke:
	python -m benchmarks.run --fast --sf 1
	python -m benchmarks.run --suite update --fast
	python -m benchmarks.run --suite optimizer --fast

bench:
	python -m benchmarks.run --sf 1

bench-update:
	python -m benchmarks.run --suite update

# operator-level inter-buffer reuse (per-operator timings + hit rates)
bench-gcdia:
	python -m benchmarks.run --suite gcdia

# cost-based optimizer: naive query-order DAG vs rewritten DAG latency
bench-optimizer:
	python -m benchmarks.run --suite optimizer --sf 2
