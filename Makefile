PYTHONPATH := src
export PYTHONPATH

.PHONY: test verify test-fast lint verify-plans bench-smoke bench bench-update bench-gcdia bench-optimizer bench-index bench-trace bench-kernels bench-shard bench-regression

# tier-1 verification (the full suite — unchanged)
test:
	python -m pytest -x -q

# alias used by CI / the verify skill: the fast tier (<60s) gates the inner
# loop; run `make test` for the full tier-1 suite
verify: test-fast

# fast tier: core engine / storage / planner / physical / optimizer /
# cardinality / write-path modules, selected by the `fast` pytest marker
test-fast:
	python -m pytest -x -q -m fast

# repo-wide AST lint (GDL001-GDL005: module-global mutable state, host
# syncs in operator hot paths, nested locks, bare excepts, mutable default
# args). Findings not in lint_baseline.json fail the build; regenerate the
# baseline with `python -m repro.analysis.lint --write-baseline` only for
# findings that are genuinely pre-existing and safe.
lint:
	python -m repro.analysis.lint

# static plan-verification sweep: every m2bench query/task x
# {gredo,dual,single} x shards {1,4} x device lowering on/off, verified
# without executing (see repro.core.verify). Report lands in
# experiments/verify_sweep.json; ERROR-severity violations fail the run.
verify-plans:
	python -m repro.analysis.verify_sweep

# small-size benchmark pass (CI smoke): paper suite fast mode + update +
# optimizer + index suites
bench-smoke:
	python -m benchmarks.run --fast --sf 1
	python -m benchmarks.run --suite update --fast
	python -m benchmarks.run --suite optimizer --fast
	python -m benchmarks.run --suite index --fast --sf 2

bench:
	python -m benchmarks.run --sf 1

bench-update:
	python -m benchmarks.run --suite update

# operator-level inter-buffer reuse (per-operator timings + hit rates)
bench-gcdia:
	python -m benchmarks.run --suite gcdia

# cost-based optimizer: naive query-order DAG vs rewritten DAG latency
bench-optimizer:
	python -m benchmarks.run --suite optimizer --sf 2

# secondary-index access paths: indexed vs full-scan latency + selectivity
# sweep + write-path maintenance overhead (--sf 80: the point lookup's full
# scans dominate the fixed executor overhead there)
bench-index:
	python -m benchmarks.run --suite index --sf 80

# telemetry smoke: one GCDIA reuse ladder traced end-to-end, Chrome-trace
# JSON exported to experiments/trace_gcdia.json (schema-validated; open in
# Perfetto), kernel roofline attribution, disabled-telemetry overhead guard
bench-trace:
	python -m benchmarks.run --suite trace --fast

# traversal kernel family: host vs jit vs fused-pallas latency ladder,
# batched point-lookup throughput, per-kernel roofline attribution
bench-kernels:
	python -m benchmarks.run --suite kernels

# perf-regression gate: re-measure the paper's headline suites (GCDI/GCDA
# ablations, inter-buffer reuse) and compare against the committed
# noise-aware baselines in experiments/bench_baselines.json; exits non-zero
# on any metric outside its tolerance band. Re-baseline with
# `python -m benchmarks.regression --update-baseline` only for accepted
# perf changes.
bench-regression:
	python -m benchmarks.regression --fast

# sharded morsel-parallel execution: single-stream vs 4-shard cold latency
# on the scan/join-heavy GCDIA (bit-for-bit checked), the born-sharded
# Rel2Matrix handoff assertion, and the small-input serial cost gate
# (--sf 200: the scans dominate the fixed executor overhead there)
bench-shard:
	python -m benchmarks.run --suite shard --sf 200
